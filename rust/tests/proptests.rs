//! Property-based tests (seeded, in-tree harness — see util::prop) over
//! the coordinator-level invariants: SLTree partitioning, traversal
//! bit-accuracy, and blending conservation laws, on randomized scenes,
//! cameras and parameters.

use sltarch::config::{DramConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer};
use sltarch::coordinator::{BatchConfig, CpuBackend, FramePipeline, RenderOptions};
use sltarch::gaussian::{
    project_into, project_into_threaded, Gaussians, Splat2D, ALPHA_THRESH,
};
use sltarch::lod::{traverse_sltree, CutCache, CutCacheConfig, SlTree};
use sltarch::math::{Camera, Intrinsics, Quat, Vec2, Vec3};
use sltarch::residency::{ResidencyConfig, ResidencyManager};
use sltarch::scene::{build_lod_tree, GeneratorKind, SceneSpec};
use sltarch::splat::blend::PIXELS;
use sltarch::splat::{
    bin_splats, bin_splats_into_threaded, bin_splats_nested, blend_tile,
    blend_tile_soa, group_keep_threshold, radix_sort_tile, radix_sort_tile_split,
    sort_bins_threaded, sort_tile_by_depth, BlendKernel, BlendMode,
    DepthSortScratch, TileBins, TileState,
};
use sltarch::util::prop::forall;
use sltarch::util::Rng;

fn random_scene(rng: &mut Rng) -> (sltarch::gaussian::Gaussians, sltarch::lod::LodTree) {
    let kinds = [GeneratorKind::Room, GeneratorKind::City, GeneratorKind::Terrain];
    let spec = SceneSpec {
        kind: kinds[rng.below(3)],
        leaves: 500 + rng.below(3_000),
        extent: rng.range(5.0, 60.0),
    };
    let seed = rng.next_u64();
    let mean_fanout = rng.range(2.0, 8.0);
    let max_fanout = 16 + rng.below(512);
    let (g, tree, _) = build_lod_tree(spec.generate(seed), seed, mean_fanout, max_fanout);
    (g, tree)
}

fn random_camera(rng: &mut Rng, extent: f32) -> Camera {
    let a = rng.range(0.0, std::f32::consts::TAU);
    let r = rng.range(0.3, 3.0) * extent;
    Camera::look_at(
        Vec3::new(r * a.cos(), rng.range(0.05, 1.2) * extent, r * a.sin()),
        Vec3::ZERO,
        Vec3::new(0.0, 1.0, 0.0),
        Intrinsics::from_fov(128, 128, 60f32.to_radians()),
    )
}

#[test]
fn prop_partition_is_exact_cover_for_any_tree_and_tau() {
    forall(12, |rng| {
        let (_, tree) = random_scene(rng);
        let tau_s = 4 + rng.below(96) as u32;
        for slt in [
            SlTree::partition(&tree, tau_s),
            SlTree::partition_unmerged(&tree, tau_s),
        ] {
            slt.check_invariants(&tree).unwrap();
            assert_eq!(slt.sizes().iter().sum::<usize>(), tree.len());
        }
    });
}

#[test]
fn prop_traversal_bit_accurate_for_any_camera_and_tau() {
    forall(10, |rng| {
        let (_, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let tau_s = 8 + rng.below(56) as u32;
        let slt = SlTree::partition(&tree, tau_s);
        for _ in 0..3 {
            let cam = random_camera(rng, extent.max(1.0));
            let tau = rng.range(0.5, 64.0);
            let (want, _) = tree.canonical_search(&cam, tau);
            let (got, trace) = traverse_sltree(&tree, &slt, &cam, tau, 1 + rng.below(8));
            assert_eq!(got, want, "cut mismatch (tau={tau}, tau_s={tau_s})");
            // The traversal never does more node tests than canonical.
            assert!(trace.visited <= {
                let (_, t) = tree.canonical_search(&cam, tau);
                t.visited
            });
        }
    });
}

#[test]
fn prop_cut_cache_is_bit_identical_across_taus_and_cameras() {
    // Tentpole contract: the temporal cut cache's incremental
    // revalidation selects exactly the canonical cut at every frame of
    // any camera sequence — even a teleporting one, with every full-
    // search fallback disabled so the incremental path itself is what
    // runs on frames 1+.
    forall(8, |rng| {
        let (_, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let tau_s = 8 + rng.below(56) as u32;
        let slt = SlTree::partition(&tree, tau_s);
        let cfg = CutCacheConfig {
            enabled: true,
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::PI,
            refresh_every: 0,
            max_tau_step: f32::INFINITY,
        };
        for tau in [rng.range(0.5, 8.0), rng.range(8.0, 64.0)] {
            let mut cache = CutCache::new();
            for i in 0..6u64 {
                let cam = random_camera(rng, extent.max(1.0));
                let (want, _) = tree.canonical_search(&cam, tau);
                let (got, trace) = cache.search(&tree, &slt, &cam, tau, &cfg);
                assert_eq!(
                    got,
                    want.as_slice(),
                    "frame {i} tau {tau} tau_s {tau_s}"
                );
                assert_eq!(trace.cache_hit, u64::from(i > 0), "frame {i}");
                assert_eq!(trace.selected, want.len() as u64);
            }
        }
    });
}

#[test]
fn prop_cut_cache_is_bit_identical_across_tau_ramps() {
    // Serving-layer contract: deadline-driven tau nudges (degrade up,
    // recover back down) within `max_tau_step` ride the incremental
    // path — every frame must be a cache hit — and still select exactly
    // the canonical cut at every step of the ramp.
    forall(8, |rng| {
        let (_, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let tau_s = 8 + rng.below(56) as u32;
        let slt = SlTree::partition(&tree, tau_s);
        let step = rng.range(1.0, 8.0);
        let cfg = CutCacheConfig {
            enabled: true,
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::PI,
            refresh_every: 0,
            max_tau_step: step,
        };
        let cam = random_camera(rng, extent.max(1.0));
        let mut tau = rng.range(4.0, 16.0);
        let mut cache = CutCache::new();
        for i in 0..10u64 {
            let (want, _) = tree.canonical_search(&cam, tau);
            let (got, trace) = cache.search(&tree, &slt, &cam, tau, &cfg);
            assert_eq!(got, want.as_slice(), "frame {i} tau {tau}");
            assert_eq!(
                trace.cache_hit,
                u64::from(i > 0),
                "nudge {i} (tau {tau}, step {step}) must stay warm"
            );
            // Ramp up for the first half (degradation), back down for
            // the second (recovery), always within the allowed step.
            let delta = rng.range(0.1, step);
            tau = if i < 5 { tau + delta } else { (tau - delta).max(0.5) };
        }
    });
}

#[test]
fn prop_cached_sessions_render_identically_across_widths() {
    // The cut cache must never change pixels: cached-path session
    // renders equal cache-disabled renders at scheduler widths
    // {1, 2, 8} along a camera path.
    forall(4, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 1_500 + rng.below(1_500);
        let pipeline = FramePipeline::builder(cfg.build(rng.next_u64())).build();
        let cams: Vec<Camera> =
            (0..4).map(|i| pipeline.scene().scenario_camera(i)).collect();
        for threads in [1usize, 2, 8] {
            let backend = CpuBackend::with_threads(threads);
            let mut cached =
                pipeline.session_on(&backend, pipeline.default_options());
            let mut cold = pipeline.session_on(
                &backend,
                RenderOptions {
                    cut_cache: CutCacheConfig::disabled(),
                    ..pipeline.default_options()
                },
            );
            let a = cached.render_path(&cams).unwrap();
            let b = cold.render_path(&cams).unwrap();
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.data, y.data, "frame {i} at {threads} threads");
            }
            assert_eq!(cold.stats().cache_hit, 0);
            assert!(cached.stats().cache_hit <= cams.len() as u64 - 1);
        }
    });
}

#[test]
fn prop_merging_never_increases_subtree_count_or_variance() {
    forall(12, |rng| {
        let (_, tree) = random_scene(rng);
        let tau_s = 8 + rng.below(56) as u32;
        let merged = SlTree::partition(&tree, tau_s);
        let unmerged = SlTree::partition_unmerged(&tree, tau_s);
        assert!(merged.len() <= unmerged.len());
        let cov = |s: &SlTree| {
            let xs: Vec<f64> = s.sizes().iter().map(|&x| x as f64).collect();
            sltarch::util::stats::cov(&xs)
        };
        // Greedy merging targets variance; allow equality for trees that
        // are already balanced.
        assert!(cov(&merged) <= cov(&unmerged) + 1e-9);
    });
}

#[test]
fn prop_blend_conserves_energy_and_bounds() {
    forall(24, |rng| {
        // Random splats over one tile; T in [0,1] decreasing, rgb bounded
        // by 1 - T (with unit colors).
        let k = 1 + rng.below(48);
        let splats: Vec<Splat2D> = (0..k)
            .map(|i| {
                let s = rng.range(0.02, 1.0);
                Splat2D {
                    mean: Vec2::new(rng.range(-4.0, 20.0), rng.range(-4.0, 20.0)),
                    conic: [s, 0.0, s],
                    depth: rng.range(0.5, 10.0),
                    radius: 3.0,
                    color: [1.0, 1.0, 1.0],
                    opacity: rng.range(0.0, 1.0),
                    id: i as u32,
                    ..Splat2D::default()
                }
                .with_keep_thresh()
            })
            .collect();
        let order: Vec<u32> = (0..k as u32).collect();
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            let mut rgb = [[0.0f32; 3]; PIXELS];
            let mut t = [1.0f32; PIXELS];
            blend_tile(&order, &splats, (0.0, 0.0), mode, &mut rgb, &mut t, 0.0);
            for p in 0..PIXELS {
                assert!((0.0..=1.0).contains(&t[p]), "T out of range: {}", t[p]);
                // With unit colours, accumulated rgb == 1 - T exactly.
                assert!(
                    (rgb[p][0] - (1.0 - t[p])).abs() < 1e-4,
                    "energy not conserved: rgb {} vs 1-T {}",
                    rgb[p][0],
                    1.0 - t[p]
                );
            }
        }
    });
}

#[test]
fn prop_soa_blend_kernel_is_bit_identical_to_scalar() {
    // The PR-5 tentpole contract at the kernel level: on random tiles
    // (random conics, opacities stressing the keep boundary, culled and
    // off-tile splats, duplicate order entries, every early-termination
    // regime) the SoA kernel reproduces `blend_tile`'s pixels AND its
    // BlendStats/DivergenceStats bit for bit, in both alpha dataflows.
    forall(24, |rng| {
        let n = 1 + rng.below(32);
        let splats: Vec<Splat2D> = (0..n)
            .map(|i| {
                let sharp = rng.range(0.02, 3.0);
                let opacity = match rng.below(8) {
                    0 => 0.0,
                    1 => 1.0,
                    2 => rng.range(0.0035, 0.0045), // ALPHA_THRESH region
                    _ => rng.range(0.01, 1.0),
                };
                Splat2D {
                    mean: Vec2::new(rng.range(-40.0, 56.0), rng.range(-40.0, 56.0)),
                    conic: [sharp, 0.0, sharp],
                    depth: rng.range(0.2, 100.0),
                    radius: if rng.below(10) == 0 { 0.0 } else { 3.0 / sharp.sqrt() },
                    color: [rng.range(0.0, 1.0), rng.range(0.0, 1.0), rng.range(0.0, 1.0)],
                    opacity,
                    id: i as u32,
                    ..Splat2D::default()
                }
                .with_keep_thresh()
            })
            .collect();
        let mut order: Vec<u32> = (0..n as u32).collect();
        if rng.below(3) == 0 {
            order.push(rng.below(n) as u32); // duplicate entry
        }
        let t_min = [0.0f32, 1.0 / 255.0, 0.5, 1.5][rng.below(4)];
        let origin = [(0.0f32, 0.0f32), (16.0, 48.0)][rng.below(2)];
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            let mut rgb = [[0.0f32; 3]; PIXELS];
            let mut t = [1.0f32; PIXELS];
            let want =
                blend_tile(&order, &splats, origin, mode, &mut rgb, &mut t, t_min);
            let mut state = TileState::fresh();
            let got =
                blend_tile_soa(&order, &splats, origin, mode, &mut state, t_min);
            assert_eq!(got, want, "{mode:?}: stats diverged");
            for p in 0..PIXELS {
                assert_eq!(
                    [state.r[p], state.g[p], state.b[p]].map(f32::to_bits),
                    rgb[p].map(f32::to_bits),
                    "{mode:?}: rgb[{p}]"
                );
                assert_eq!(state.t[p].to_bits(), t[p].to_bits(), "{mode:?}: t[{p}]");
            }
        }
    });
}

#[test]
fn prop_group_keep_threshold_matches_exp_form() {
    // The no-exp compare is exact: for random opacities (including the
    // ALPHA_THRESH boundary region) and powers — random plus the ulp
    // neighbourhood of the threshold itself — `power >= thr` equals the
    // reference exp-form keep decision.
    use sltarch::gaussian::{ALPHA_CLAMP, ALPHA_THRESH};
    forall(64, |rng| {
        let opacity = match rng.below(4) {
            0 => rng.range(0.0, 0.008),
            1 => rng.range(0.9, 1.0),
            _ => rng.range(0.0, 1.0),
        };
        let thr = group_keep_threshold(opacity);
        let mut powers: Vec<f32> =
            (0..64).map(|_| -rng.range(0.0, 9.0)).collect();
        powers.push(0.0);
        if thr.is_finite() {
            // thr is <= 0 here, so stepping the bit pattern up moves
            // toward 0 and down moves toward -inf.
            for ulps in 1u32..=4 {
                powers.push(f32::from_bits(thr.to_bits() - ulps)); // above
                powers.push(f32::from_bits(thr.to_bits() + ulps)); // below
            }
            powers.push(thr);
        }
        for &p in &powers {
            if !(p <= 0.0) {
                continue; // gauss_power domain is <= 0
            }
            let galpha = (opacity * p.exp()).min(ALPHA_CLAMP);
            let want = galpha >= ALPHA_THRESH && opacity > 0.0;
            assert_eq!(p >= thr, want, "opacity {opacity} power {p}");
        }
    });
}

#[test]
fn prop_keep_threshold_table_is_bit_identical_to_recompute() {
    // PR-8 tentpole contract: the per-splat keep threshold hoisted to
    // projection time ([`Splat2D::keep_thresh`]) is the exact
    // `group_keep_threshold` table entry, bit for bit — visible splats
    // carry their opacity's threshold, culled splats carry the
    // keep-nothing sentinel (+inf).
    forall(8, |rng| {
        let (g, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let cam = random_camera(rng, extent.max(1.0));
        let mut splats = Vec::new();
        project_into(&g, &cam, &mut splats);
        for s in &splats {
            if s.visible() {
                assert_eq!(
                    s.keep_thresh.to_bits(),
                    group_keep_threshold(s.opacity).to_bits(),
                    "splat {} threshold drifted from recompute",
                    s.id
                );
            } else {
                assert_eq!(
                    s.keep_thresh.to_bits(),
                    f32::INFINITY.to_bits(),
                    "culled splat {} must keep nothing",
                    s.id
                );
            }
        }
        // The literal-construction path (`with_keep_thresh`) fills the
        // same table entry for any opacity, including the edge cases
        // the blend kernels rely on: NaN and sub-ALPHA_THRESH
        // opacities must map to the +inf keep-nothing sentinel.
        for _ in 0..64 {
            let opacity = match rng.below(6) {
                0 => 0.0,
                1 => f32::NAN,
                2 => rng.range(0.0, ALPHA_THRESH), // below the keep floor
                3 => rng.range(0.0035, 0.0045),    // ALPHA_THRESH region
                _ => rng.range(0.0, 1.0),
            };
            let s = Splat2D { opacity, ..Splat2D::default() }.with_keep_thresh();
            assert_eq!(
                s.keep_thresh.to_bits(),
                group_keep_threshold(opacity).to_bits(),
                "with_keep_thresh diverged at opacity {opacity}"
            );
            if opacity.is_nan() || opacity < ALPHA_THRESH {
                assert_eq!(s.keep_thresh.to_bits(), f32::INFINITY.to_bits());
            }
        }
    });
}

#[test]
fn prop_degenerate_splats_never_reach_a_tile_bin() {
    // The PR-8 hardening contract, fuzz-backed: however broken the
    // inputs — non-finite means, exploding or zero scales — projection
    // either emits a fully finite splat or culls it (radius == 0 with
    // keep_thresh == +inf), and the rect/binning stage never admits a
    // non-finite splat into any tile (the old NaN -> tile (0,0) bug).
    forall(12, |rng| {
        let mut g = Gaussians::default();
        let n = 32 + rng.below(96);
        for _ in 0..n {
            let coord = |rng: &mut Rng| match rng.below(6) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => rng.range(-1e30, 1e30),
                _ => rng.range(-20.0, 20.0),
            };
            let scale = match rng.below(5) {
                0 => Vec3::splat(1e25), // cov2d overflow -> inf radius
                1 => Vec3::splat(0.0),  // det underflow
                2 => Vec3::new(f32::NAN, 0.1, 0.1),
                _ => Vec3::splat(rng.range(0.01, 2.0)),
            };
            g.push(
                Vec3::new(coord(rng), coord(rng), coord(rng)),
                scale,
                Quat::IDENTITY,
                [0.5; 3],
                rng.range(0.0, 1.0),
            );
        }
        let cam = random_camera(rng, 10.0);
        let mut splats = Vec::new();
        project_into(&g, &cam, &mut splats);
        for s in &splats {
            if s.visible() {
                assert!(
                    s.mean.x.is_finite()
                        && s.mean.y.is_finite()
                        && s.conic.iter().all(|c| c.is_finite())
                        && s.depth.is_finite()
                        && s.radius.is_finite(),
                    "projection emitted a degenerate visible splat: {s:?}"
                );
            } else {
                assert_eq!(s.keep_thresh.to_bits(), f32::INFINITY.to_bits());
            }
        }
        // Belt and braces: hand-built non-finite splats (as a buggy
        // upstream producer might emit) must bounce off the rect stage
        // instead of landing in tile (0, 0).
        let base = splats.len() as u32;
        for (k, &v) in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY].iter().enumerate() {
            splats.push(
                Splat2D {
                    mean: if k % 2 == 0 {
                        Vec2::new(v, 8.0)
                    } else {
                        Vec2::new(8.0, v)
                    },
                    conic: [1.0, 0.0, 1.0],
                    depth: 1.0,
                    radius: 3.0,
                    color: [1.0; 3],
                    opacity: 0.5,
                    id: base + k as u32,
                    ..Splat2D::default()
                }
                .with_keep_thresh(),
            );
        }
        splats.push(
            Splat2D {
                mean: Vec2::new(8.0, 8.0),
                conic: [1.0, 0.0, 1.0],
                depth: 1.0,
                radius: f32::INFINITY, // covers-everything radius
                color: [1.0; 3],
                opacity: 0.5,
                id: base + 3,
                ..Splat2D::default()
            }
            .with_keep_thresh(),
        );
        let bins = bin_splats(&splats, 128, 128);
        for t in 0..bins.tile_count() {
            for &i in bins.tile(t) {
                let s = &splats[i as usize];
                assert!(
                    s.mean.x.is_finite()
                        && s.mean.y.is_finite()
                        && s.radius.is_finite(),
                    "non-finite splat {i} reached tile {t}"
                );
            }
        }
    });
}

#[test]
fn prop_soa_kernel_sessions_match_scalar_across_widths() {
    // Session-level: a kernel=Soa session renders byte-identical frames
    // to a kernel=Scalar session for both alpha modes at scheduler
    // widths {1, 2, 8}, on randomized scenes and cameras.
    forall(4, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 1_500 + rng.below(1_500);
        let pipeline = FramePipeline::builder(cfg.build(rng.next_u64())).build();
        let cam = pipeline.scene().scenario_camera(rng.below(6));
        for alpha in [AlphaMode::Pixel, AlphaMode::Group] {
            for threads in [1usize, 2, 8] {
                let backend = CpuBackend::with_threads(threads);
                let mut scalar = pipeline.session_on(
                    &backend,
                    RenderOptions {
                        alpha,
                        kernel: BlendKernel::Scalar,
                        ..pipeline.default_options()
                    },
                );
                let mut soa = pipeline.session_on(
                    &backend,
                    RenderOptions {
                        alpha,
                        kernel: BlendKernel::Soa,
                        ..pipeline.default_options()
                    },
                );
                let want = scalar.render(&cam).unwrap();
                let got = soa.render(&cam).unwrap();
                assert_eq!(
                    want.data, got.data,
                    "SoA kernel diverged ({alpha:?}, {threads} threads)"
                );
            }
        }
    });
}

fn random_screen_splats(rng: &mut Rng) -> Vec<Splat2D> {
    // Sized to straddle the parallel front end's serial-fallback
    // threshold (1024), so both code paths see coverage.
    let n = 1 + rng.below(2_400);
    (0..n)
        .map(|i| {
            let s = rng.range(0.02, 1.0);
            Splat2D {
                // Deliberately includes off-screen and culled splats.
                mean: Vec2::new(rng.range(-80.0, 340.0), rng.range(-80.0, 340.0)),
                conic: [s, 0.0, s],
                depth: if rng.below(4) == 0 {
                    [0.5f32, 1.0, 7.25][rng.below(3)] // force depth ties
                } else {
                    rng.range(0.2, 1e5)
                },
                radius: if rng.below(10) == 0 { 0.0 } else { rng.range(0.5, 64.0) },
                color: [1.0; 3],
                opacity: 0.5,
                id: i as u32,
                ..Splat2D::default()
            }
            .with_keep_thresh()
        })
        .collect()
}

#[test]
fn prop_chunked_projection_matches_serial_for_any_scene() {
    // Tentpole contract 1/3: the chunked multi-threaded projection is
    // byte-identical to the serial path at widths {1, 2, 8} on
    // randomized scenes and cameras.
    forall(8, |rng| {
        let (g, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let cam = random_camera(rng, extent.max(1.0));
        let mut serial = Vec::new();
        project_into(&g, &cam, &mut serial);
        let mut par = Vec::new();
        for threads in [1usize, 2, 8] {
            project_into_threaded(&g, &cam, &mut par, threads);
            assert_eq!(par.len(), serial.len(), "{threads} threads");
            for (a, b) in par.iter().zip(serial.iter()) {
                assert_eq!(a.bit_pattern(), b.bit_pattern(), "{threads} threads");
            }
        }
    });
}

#[test]
fn prop_parallel_bins_match_nested_reference() {
    // Tentpole contract 2/3: the per-worker-histogram parallel binning
    // produces CSR arrays byte-identical to the nested reference (and
    // therefore to the serial CSR build) at widths {1, 2, 8}.
    forall(12, |rng| {
        let splats = random_screen_splats(rng);
        let (w, h) = (16 + rng.below(300) as u32, 16 + rng.below(300) as u32);
        let (nested, pairs) = bin_splats_nested(&splats, w, h);
        for threads in [1usize, 2, 8] {
            let mut bins = TileBins::default();
            bin_splats_into_threaded(&splats, w, h, &mut bins, threads).unwrap();
            bins.validate_csr(splats.len()).unwrap();
            assert_eq!(bins.pairs, pairs, "{threads} threads");
            for t in 0..nested.len() {
                assert_eq!(
                    bins.tile(t),
                    nested[t].as_slice(),
                    "tile {t} at {threads} threads"
                );
            }
        }
    });
}

#[test]
fn prop_parallel_tile_sort_matches_reference() {
    // Tentpole contract 3/3: the dynamic-cursor parallel tile sort
    // equals the comparison reference sort on every tile at widths
    // {1, 2, 8}.
    forall(12, |rng| {
        let splats = random_screen_splats(rng);
        let (w, h) = (16 + rng.below(300) as u32, 16 + rng.below(300) as u32);
        let unsorted = bin_splats(&splats, w, h);
        let mut want = unsorted.clone();
        for t in 0..want.tile_count() {
            sort_tile_by_depth(want.tile_mut(t), &splats);
        }
        for threads in [1usize, 2, 8] {
            let mut got = unsorted.clone();
            let mut pool = Vec::new();
            sort_bins_threaded(&mut got, &splats, &mut pool, threads);
            assert_eq!(got.offsets, want.offsets, "{threads} threads");
            assert_eq!(got.indices, want.indices, "{threads} threads");
        }
    });
}

#[test]
fn prop_csr_bins_match_nested_reference() {
    forall(32, |rng| {
        let splats = random_screen_splats(rng);
        let (w, h) = (16 + rng.below(300) as u32, 16 + rng.below(300) as u32);
        let bins = bin_splats(&splats, w, h);
        let (nested, pairs) = bin_splats_nested(&splats, w, h);
        assert_eq!(bins.pairs, pairs);
        assert_eq!(bins.tile_count(), nested.len());
        for t in 0..nested.len() {
            assert_eq!(bins.tile(t), nested[t].as_slice(), "tile {t}");
        }
    });
}

#[test]
fn prop_view_batch_matches_independent_sessions_across_widths() {
    // PR-10 tentpole contract: a ViewBatch render of K cameras is
    // byte-identical to K independent session renders — with every
    // sharing level on and with all sharing off — at scheduler widths
    // {1, 2, 8}, and the deterministic RenderStats counters agree per
    // view (cache counters too in independent mode).
    forall(2, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 1_500 + rng.below(1_500);
        let pipeline = FramePipeline::builder(cfg.build(rng.next_u64())).build();
        for k in [1usize, 2, 4] {
            // Orbit poses plus an exact duplicate when K allows, so
            // identity coalescing and seed grouping both get a chance
            // to fire (correctness must hold whether or not they do).
            let mut cams: Vec<Camera> =
                (0..k).map(|i| pipeline.scene().scenario_camera(i % 6)).collect();
            if k >= 3 {
                cams[2] = cams[0];
            }
            for threads in [1usize, 2, 8] {
                let backend = CpuBackend::with_threads(threads);
                for bcfg in [BatchConfig::default(), BatchConfig::independent()] {
                    let mut batch =
                        pipeline.batch_on(&backend, pipeline.default_options(), bcfg);
                    let imgs = batch.render(&cams).unwrap();
                    let independent = !bcfg.share_front_ends && !bcfg.seed_searches;
                    for (v, cam) in cams.iter().enumerate() {
                        let mut solo =
                            pipeline.session_on(&backend, pipeline.default_options());
                        let want = solo.render(cam).unwrap();
                        assert_eq!(
                            imgs[v].data, want.data,
                            "view {v}/{k} diverged at {threads} threads ({bcfg:?})"
                        );
                        let vs = batch.view_stats(v).unwrap();
                        let ss = solo.stats();
                        assert_eq!(vs.frames, ss.frames, "view {v}");
                        assert_eq!(vs.cut_total, ss.cut_total, "view {v}");
                        assert_eq!(vs.pairs_total, ss.pairs_total, "view {v}");
                        assert_eq!(vs.threads, ss.threads, "view {v}");
                        assert_eq!(
                            vs.front_end_threads, ss.front_end_threads,
                            "view {v}"
                        );
                        if independent {
                            assert_eq!(vs.cache_hit, ss.cache_hit, "view {v}");
                            assert_eq!(vs.revalidated, ss.revalidated, "view {v}");
                            assert_eq!(vs.reseeded, ss.reseeded, "view {v}");
                            assert_eq!(
                                vs.verdicts_skipped, ss.verdicts_skipped,
                                "view {v}"
                            );
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_fused_radix_sort_matches_split_reference() {
    // PR-10 satellite: the fused count-into-scatter radix sort (one
    // pass fewer over the keys) must order every random tile exactly
    // like the split count-then-scatter reference — which is itself
    // pinned to the comparison sort above.
    forall(48, |rng| {
        let splats = random_screen_splats(rng);
        let mut fused_scratch = DepthSortScratch::new();
        let mut split_scratch = DepthSortScratch::new();
        for _ in 0..4 {
            let k = 1 + rng.below(splats.len());
            let mut idx: Vec<u32> = (0..splats.len() as u32).collect();
            for i in (1..idx.len()).rev() {
                idx.swap(i, rng.below(i + 1));
            }
            idx.truncate(k);
            let mut want = idx.clone();
            radix_sort_tile_split(&mut want, &splats, &mut split_scratch);
            let mut got = idx;
            radix_sort_tile(&mut got, &splats, &mut fused_scratch);
            assert_eq!(got, want);
        }
    });
}

#[test]
fn prop_radix_order_equals_comparison_sort() {
    forall(48, |rng| {
        let splats = random_screen_splats(rng);
        let mut scratch = DepthSortScratch::new();
        // Random subsets in random order, as tile bins would hold.
        for _ in 0..4 {
            let k = 1 + rng.below(splats.len());
            let mut idx: Vec<u32> = (0..splats.len() as u32).collect();
            for i in (1..idx.len()).rev() {
                idx.swap(i, rng.below(i + 1));
            }
            idx.truncate(k);
            let mut want = idx.clone();
            sort_tile_by_depth(&mut want, &splats);
            let mut got = idx;
            radix_sort_tile(&mut got, &splats, &mut scratch);
            assert_eq!(got, want);
        }
    });
}

#[test]
fn prop_session_render_is_bit_identical_to_seed_per_frame_path() {
    // The api_redesign acceptance bar: RenderSession::render must be
    // bit-identical to the pre-session per-frame path (the stateless
    // CpuRenderer over pipeline.search) for both alpha dataflows and
    // tile-scheduler widths 1/4/8, on randomized scenes and cameras.
    forall(6, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 2_000 + rng.below(2_000);
        let pipeline = FramePipeline::builder(cfg.build(rng.next_u64())).build();
        let cam = pipeline.scene().scenario_camera(rng.below(6));
        let cut = pipeline.search(&cam);
        let queue = pipeline.scene().gaussians.gather(&cut);
        for alpha in [AlphaMode::Pixel, AlphaMode::Group] {
            for threads in [1usize, 4, 8] {
                let backend = CpuBackend::with_threads(threads);
                let mut session = pipeline.session_on(
                    &backend,
                    RenderOptions { alpha, ..pipeline.default_options() },
                );
                let got = session.render(&cam).unwrap();
                let want =
                    CpuRenderer::render_threaded(&queue, &cam, alpha, pipeline.rcfg(), threads);
                assert_eq!(
                    got.data, want.data,
                    "session diverged from seed path ({alpha:?}, {threads} threads)"
                );
                let stats = session.stats();
                assert_eq!(stats.frames, 1);
                assert_eq!(stats.cut_total, cut.len() as u64);
                assert_eq!(stats.threads, threads);
                // One knob: the front end ran at the same width.
                assert_eq!(stats.front_end_threads, threads);
                assert!(stats.stages.staged_total() <= stats.wall_seconds + 1e-9);
            }
        }
    });
}

#[test]
fn prop_residency_resident_bytes_never_exceed_budget() {
    // PR-7 tentpole invariant: whatever the scene, access pattern,
    // budget or prefetch setting, the manager never holds more than its
    // byte budget after a frame — bypass loads make this unconditional
    // even when one frame's pinned cut alone exceeds the budget.
    forall(8, |rng| {
        let (_, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let tau_s = 8 + rng.below(56) as u32;
        let slt = SlTree::partition(&tree, tau_s);
        let total: u64 = slt.subtrees.iter().map(|s| s.bytes()).sum();
        let cfg = ResidencyConfig {
            enabled: true,
            budget_bytes: 1 + rng.next_u64() % total,
            prefetch: rng.below(2) == 0,
        };
        let dram = DramConfig::default();
        let mut mgr = ResidencyManager::new();
        for _ in 0..6 {
            let cam = random_camera(rng, extent.max(1.0));
            let tau = rng.range(0.5, 64.0);
            let (cut, trace) = traverse_sltree(&tree, &slt, &cam, tau, 4);
            let delta =
                mgr.charge_frame(&slt, &cut, &[&trace.activation_sids], &cfg, &dram);
            assert!(
                mgr.resident_bytes() <= cfg.budget_bytes,
                "resident {} > budget {}",
                mgr.resident_bytes(),
                cfg.budget_bytes
            );
            assert_eq!(delta.frames, 1);
        }
    });
}

#[test]
fn prop_residency_never_evicts_current_cut_slabs() {
    // The pin contract: while a frame is being charged, the slabs its
    // cut lives in are pinned — no amount of LRU pressure from other
    // slab accesses within the frame may evict them.
    forall(8, |rng| {
        let (_, tree) = random_scene(rng);
        let extent = tree.aabbs[0].half_extent().max_component();
        let tau_s = 8 + rng.below(56) as u32;
        let slt = SlTree::partition(&tree, tau_s);
        let cam = random_camera(rng, extent.max(1.0));
        let tau = rng.range(2.0, 32.0);
        let (cut, trace) = traverse_sltree(&tree, &slt, &cam, tau, 4);
        // Budget: the frame's activated working set plus one slab, so
        // the flood of extra accesses below must evict to admit.
        let mut active = trace.activation_sids.clone();
        active.sort_unstable();
        active.dedup();
        let active_bytes: u64 =
            active.iter().map(|&s| slt.subtrees[s as usize].bytes()).sum();
        let cfg = ResidencyConfig::with_budget(
            active_bytes + slt.subtrees[slt.top as usize].bytes(),
        );
        let dram = DramConfig::default();
        let mut mgr = ResidencyManager::new();
        mgr.charge_frame(&slt, &cut, &[&trace.activation_sids], &cfg, &dram);
        // Same frame again under pressure: every slab in the tree
        // hammers the LRU, but the current cut's slabs are pinned.
        let others: Vec<u32> = (0..slt.subtrees.len() as u32).collect();
        mgr.charge_frame(
            &slt,
            &cut,
            &[&trace.activation_sids, &others],
            &cfg,
            &dram,
        );
        assert!(mgr.resident_bytes() <= cfg.budget_bytes);
        for &n in &cut {
            let sid = slt.node_sid[n as usize];
            assert!(mgr.is_resident(sid), "cut slab {sid} evicted under pressure");
        }
    });
}

#[test]
fn prop_residency_sessions_render_identically_across_widths() {
    // The PR-7 acceptance bar: a residency-managed session (budget
    // tight enough to force constant eviction and bypass) renders
    // byte-identical frames to an unmanaged session at scheduler widths
    // {1, 2, 8} along a camera path.
    forall(4, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 1_500 + rng.below(1_500);
        let pipeline = FramePipeline::builder(cfg.build(rng.next_u64())).build();
        let slt = pipeline.sltree();
        let budget = 3 * slt.subtrees[slt.top as usize].bytes().max(1);
        let cams: Vec<Camera> =
            (0..4).map(|i| pipeline.scene().scenario_camera(i)).collect();
        for threads in [1usize, 2, 8] {
            let backend = CpuBackend::with_threads(threads);
            let mut managed = pipeline.session_on(
                &backend,
                RenderOptions {
                    residency: ResidencyConfig::with_budget(budget),
                    ..pipeline.default_options()
                },
            );
            let mut plain =
                pipeline.session_on(&backend, pipeline.default_options());
            let a = managed.render_path(&cams).unwrap();
            let b = plain.render_path(&cams).unwrap();
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.data, y.data, "frame {i} at {threads} threads");
            }
            let rs = managed.stats().residency;
            assert_eq!(rs.frames, cams.len() as u64);
            assert!(rs.misses > 0, "tight budget must demand-fault");
            assert_eq!(plain.stats().residency.frames, 0);
        }
    });
}

#[test]
fn prop_scene_presets_build_valid_pipelines() {
    forall(4, |rng| {
        let mut cfg = SceneConfig::small_scale().quick();
        cfg.leaves = 1_000 + rng.below(2_000);
        let scene = cfg.build(rng.next_u64());
        scene.tree.check_invariants().unwrap();
        let slt = SlTree::partition(&scene.tree, 32);
        slt.check_invariants(&scene.tree).unwrap();
    });
}
