//! Integration: the PJRT path (AOT JAX/Pallas artifacts executed via the
//! xla crate) must agree numerically with the rust CPU mirror — the
//! cross-layer correctness contract of the three-layer architecture.
//!
//! Requires `make artifacts` (skips with a loud message otherwise so
//! plain `cargo test` works on a fresh checkout).

use sltarch::config::{RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer, PjrtRenderer};
use sltarch::coordinator::{FramePipeline, RenderOptions};
use sltarch::gaussian::project;
use sltarch::lod::SlTree;
use sltarch::runtime::{default_artifacts_dir, ArtifactSet, PjrtEngine, ProjectBatch};

fn engine_or_skip() -> Option<(ArtifactSet, PjrtEngine)> {
    match ArtifactSet::discover(&default_artifacts_dir()) {
        Ok(set) => {
            let engine = PjrtEngine::load(&set).expect("compiling artifacts");
            Some((set, engine))
        }
        Err(e) => {
            eprintln!("SKIP pjrt_roundtrip: {e}");
            None
        }
    }
}

#[test]
fn projection_artifact_matches_cpu_mirror() {
    let Some((_, engine)) = engine_or_skip() else { return };
    let scene = SceneConfig::small_scale().quick().build(21);
    let cam = scene.scenario_camera(0);
    // Take a modest prefix so the test stays fast.
    let idx: Vec<u32> = (0..600u32).collect();
    let queue = scene.gaussians.gather(&idx);

    let got = ProjectBatch::run(&engine, &queue, &cam).expect("pjrt projection");
    let want = project(&queue, &cam);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want.iter()) {
        assert!(
            (g.depth - w.depth).abs() <= 1e-3 * w.depth.abs().max(1.0),
            "depth mismatch: {} vs {}",
            g.depth,
            w.depth
        );
        if w.visible() {
            assert!((g.mean.x - w.mean.x).abs() < 0.05, "{:?} vs {:?}", g.mean, w.mean);
            assert!((g.mean.y - w.mean.y).abs() < 0.05);
            for c in 0..3 {
                let rel = (g.conic[c] - w.conic[c]).abs()
                    / w.conic[c].abs().max(1e-3);
                assert!(rel < 2e-2, "conic[{c}]: {:?} vs {:?}", g.conic, w.conic);
            }
            assert!((g.radius - w.radius).abs() <= 1.0);
        } else {
            assert!(!g.visible(), "visibility mismatch at id {}", w.id);
        }
    }
}

#[test]
fn full_render_pjrt_matches_cpu() {
    let Some((_, engine)) = engine_or_skip() else { return };
    let scene = SceneConfig::small_scale().quick().build(22);
    let cam = scene.scenario_camera(1);
    let rcfg = RenderConfig::default();
    let slt = SlTree::partition(&scene.tree, rcfg.subtree_size);
    let cut = slt.traverse(&scene.tree, &cam, rcfg.lod_tau);
    let queue = scene.gaussians.gather(&cut);

    for mode in [AlphaMode::Pixel, AlphaMode::Group] {
        let cpu = CpuRenderer::render(&queue, &cam, mode, &rcfg);
        let pjrt = PjrtRenderer::render(&engine, &queue, &cam, mode, &rcfg)
            .expect("pjrt render");
        let mad = cpu.mad(&pjrt);
        // Early-termination boundaries may differ by one chunk; the
        // images must still agree to well under one grey level.
        assert!(mad < 2e-3, "{mode:?}: CPU vs PJRT mad {mad}");
    }
}

#[test]
fn pjrt_session_matches_stateless_pjrt_renderer() {
    // The backend-agnostic session front end must feed the PJRT blend
    // path the same sorted bins the stateless reference does.
    let Some((_, engine)) = engine_or_skip() else { return };
    let scene = SceneConfig::small_scale().quick().build(24);
    let pipeline = FramePipeline::builder(scene).engine(engine).build();
    let cam = pipeline.scene().scenario_camera(1);
    let cut = pipeline.search(&cam);
    let queue = pipeline.scene().gaussians.gather(&cut);
    for alpha in [AlphaMode::Pixel, AlphaMode::Group] {
        let mut session =
            pipeline.session_with(RenderOptions { alpha, ..pipeline.default_options() });
        let got = session.render(&cam).expect("session render");
        // The session really went through the PJRT backend.
        assert_eq!(pipeline.backend().name(), "pjrt");
        let want = CpuRenderer::render(&queue, &cam, alpha, pipeline.rcfg());
        let mad = got.mad(&want);
        assert!(mad < 2e-3, "{alpha:?}: session-PJRT vs CPU mad {mad}");
        assert_eq!(session.stats().threads, 0, "PJRT sessions report 0 threads");
    }
}

#[test]
fn pjrt_group_mode_differs_from_pixel_mode_but_slightly() {
    let Some((_, engine)) = engine_or_skip() else { return };
    let scene = SceneConfig::small_scale().quick().build(23);
    let cam = scene.scenario_camera(0);
    let rcfg = RenderConfig::default();
    let slt = SlTree::partition(&scene.tree, rcfg.subtree_size);
    let cut = slt.traverse(&scene.tree, &cam, rcfg.lod_tau);
    let queue = scene.gaussians.gather(&cut);
    let px = PjrtRenderer::render(&engine, &queue, &cam, AlphaMode::Pixel, &rcfg).unwrap();
    let gp = PjrtRenderer::render(&engine, &queue, &cam, AlphaMode::Group, &rcfg).unwrap();
    let mad = px.mad(&gp);
    assert!(mad > 0.0, "group mode must actually differ");
    assert!(mad < 0.02, "group approximation too lossy through PJRT: {mad}");
}
