//! Real-asset ingestion suite: encoder/parser round trips, the
//! checked-in fixture zoo, and degenerate-input fuzzing.
//!
//! The contracts pinned here (ISSUE/ROADMAP "real-asset ingestion"):
//!
//! * **Round trips.** Any procedural batch written through the PLY
//!   encoder reloads with raw f32 fields bit-exact and activated fields
//!   within ulps; from the first load onward the PLY cycle is **bitwise
//!   idempotent**, so round-tripped renders are byte-identical. The
//!   `.splat` cycle is exact on positions/scales and within `u8`
//!   quantization elsewhere; its renders are digest-stable across
//!   scheduler widths {1, 8}.
//! * **Fixture zoo.** The checked-in files under `tests/fixtures/` load
//!   with the exact kept/dropped counters they were built with, and the
//!   zoo scenes render through a real `RenderSession` (golden digests
//!   for them live in `tests/golden.rs`).
//! * **Fuzzing.** Truncation at every byte offset, NaN/±inf fields,
//!   zero-norm quaternions, shuffled/unknown/absurd headers and raw
//!   random bytes: strict mode returns the right [`AssetError`]
//!   variant, lossy mode never panics and never emits a splat the
//!   PR-8-hardened projection would have to cull
//!   ([`sltarch::assets::splat_defect`] is that invariant).

use std::path::{Path, PathBuf};

use sltarch::assets::{
    assemble_scene, load_ply, load_scene, load_splat, splat_defect,
    write_ply, write_splat, AssembleOptions, AssetError, LoadMode,
    SPLAT_RECORD_BYTES,
};
use sltarch::coordinator::{CpuBackend, FramePipeline};
use sltarch::gaussian::Gaussians;
use sltarch::math::{Quat, Vec3};
use sltarch::util::prop::forall;
use sltarch::util::Rng;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// A random well-formed batch: arbitrary (non-unit) quats, sane ranges.
fn random_batch(rng: &mut Rng, n: usize) -> Gaussians {
    let mut g = Gaussians::with_capacity(n);
    for _ in 0..n {
        let w = (0.2 + rng.f32()) * if rng.below(2) == 0 { 1.0 } else { -1.0 };
        g.push(
            Vec3::new(
                rng.range(-5.0, 5.0),
                rng.range(-2.0, 2.0),
                rng.range(-5.0, 5.0),
            ),
            Vec3::new(
                rng.range(0.05, 0.5),
                rng.range(0.05, 0.5),
                rng.range(0.05, 0.5),
            ),
            Quat::new(
                w,
                rng.range(-1.0, 1.0),
                rng.range(-1.0, 1.0),
                rng.range(-1.0, 1.0),
            ),
            [rng.f32(), rng.f32(), rng.f32()],
            rng.range(0.05, 0.99),
        );
    }
    g
}

fn assert_batches_bitwise_equal(a: &Gaussians, b: &Gaussians, what: &str) {
    assert_eq!(a.means, b.means, "{what}: means");
    assert_eq!(a.scales, b.scales, "{what}: scales");
    assert_eq!(a.quats, b.quats, "{what}: quats");
    assert_eq!(a.colors, b.colors, "{what}: colors");
    assert_eq!(a.opacity, b.opacity, "{what}: opacity");
}

fn assert_all_well_formed(g: &Gaussians, what: &str) {
    for i in 0..g.len() {
        assert_eq!(
            splat_defect(g, i),
            None,
            "{what}: kept splat {i} is degenerate"
        );
    }
}

/// Render one frame of an assembled scene at the given scheduler width.
fn render_once(
    leaves: Gaussians,
    threads: usize,
) -> sltarch::metrics::Image {
    let scene = assemble_scene(leaves, &AssembleOptions::default()).unwrap();
    let cam = scene.scenario_camera(0);
    let pipeline =
        FramePipeline::builder(scene).tau(16.0).subtree_size(32).build();
    let backend = CpuBackend::with_threads(threads);
    let mut session = pipeline.session_on(&backend, pipeline.default_options());
    session.render(&cam).expect("render")
}

// ---------------------------------------------------------------------------
// Satellite 1: round-trip property tests.

#[test]
fn ply_round_trip_exact_fields_and_bitwise_idempotence() {
    forall(24, |rng| {
        let n = 1 + rng.below(40);
        let g0 = random_batch(rng, n);
        let mut bytes = Vec::new();
        write_ply(&mut bytes, &g0).unwrap();
        let g1 = load_ply(&bytes[..], LoadMode::Strict).unwrap().gaussians;
        assert_eq!(g1.len(), n);

        // Raw f32 fields survive bit-exact; activated fields (color,
        // opacity, log-scale) land within the activation's image
        // spacing; quats equal the f64-normalized originals.
        assert_eq!(g1.means, g0.means, "positions must be exact");
        for i in 0..n {
            for k in 0..3 {
                assert!(
                    (g1.colors[i][k] - g0.colors[i][k]).abs() < 1e-5,
                    "color[{i}][{k}]: {} vs {}",
                    g1.colors[i][k],
                    g0.colors[i][k]
                );
                let rel = (g1.scales[i][k] - g0.scales[i][k]).abs()
                    / g0.scales[i][k];
                assert!(rel < 1e-5, "scale[{i}][{k}] rel err {rel}");
            }
            assert!((g1.opacity[i] - g0.opacity[i]).abs() < 1e-5, "[{i}]");
            let q = g0.quats[i];
            let norm: f64 =
                q.iter().map(|&c| c as f64 * c as f64).sum::<f64>().sqrt();
            for k in 0..4 {
                let want = (q[k] as f64 / norm) as f32;
                assert!(
                    (g1.quats[i][k] - want).abs() < 1e-5,
                    "quat[{i}][{k}]"
                );
            }
        }

        // From the first load on, the cycle is bitwise idempotent.
        let mut bytes2 = Vec::new();
        write_ply(&mut bytes2, &g1).unwrap();
        let g2 = load_ply(&bytes2[..], LoadMode::Strict).unwrap().gaussians;
        assert_batches_bitwise_equal(&g1, &g2, "ply idempotence");
    });
}

#[test]
fn splat_round_trip_within_quantization() {
    forall(24, |rng| {
        let n = 1 + rng.below(40);
        let g0 = random_batch(rng, n);
        let mut bytes = Vec::new();
        write_splat(&mut bytes, &g0).unwrap();
        let g1 = load_splat(&bytes[..], LoadMode::Strict).unwrap().gaussians;
        assert_eq!(g1.len(), n);
        // Positions and scales are raw f32 in this format: bit-exact.
        assert_eq!(g1.means, g0.means, "positions must be exact");
        assert_eq!(g1.scales, g0.scales, "scales must be exact");
        for i in 0..n {
            for k in 0..3 {
                assert!(
                    (g1.colors[i][k] - g0.colors[i][k]).abs()
                        <= 0.5 / 255.0 + 1e-6
                );
            }
            assert!(
                (g1.opacity[i] - g0.opacity[i]).abs() <= 0.5 / 255.0 + 1e-6
            );
            let q = g0.quats[i];
            let norm: f64 =
                q.iter().map(|&c| c as f64 * c as f64).sum::<f64>().sqrt();
            for k in 0..4 {
                let want = (q[k] as f64 / norm) as f32;
                // One quantization step plus renormalization slack.
                assert!(
                    (g1.quats[i][k] - want).abs() <= 1.0 / 128.0 + 1e-2,
                    "quat[{i}][{k}]: {} vs {want}",
                    g1.quats[i][k]
                );
            }
        }
    });
}

#[test]
fn ply_round_trip_renders_byte_identical() {
    // PLY: the loaded batch is bitwise stable under encode+load, so the
    // round-tripped scene renders byte-identical frames — checked both
    // against the re-round-tripped scene and across widths {1, 8}.
    let mut rng = Rng::new(0xA55E7);
    let g0 = random_batch(&mut rng, 400);
    let mut bytes = Vec::new();
    write_ply(&mut bytes, &g0).unwrap();
    let g1 = load_ply(&bytes[..], LoadMode::Strict).unwrap().gaussians;
    let mut bytes2 = Vec::new();
    write_ply(&mut bytes2, &g1).unwrap();
    let g2 = load_ply(&bytes2[..], LoadMode::Strict).unwrap().gaussians;

    let f1w1 = render_once(g1.clone(), 1);
    let f1w8 = render_once(g1, 8);
    let f2w1 = render_once(g2, 1);
    assert_eq!(f1w1.data, f1w8.data, "ply round trip: width 8 diverged");
    assert_eq!(f1w1.data, f2w1.data, "ply round trip: re-encode diverged");
}

#[test]
fn splat_round_trip_renders_digest_stable() {
    // .splat: quantized, so only the loaded scene's own digests are
    // pinned — identical across scheduler widths {1, 8}.
    let mut rng = Rng::new(0xB44D9);
    let g0 = random_batch(&mut rng, 400);
    let mut bytes = Vec::new();
    write_splat(&mut bytes, &g0).unwrap();
    let g1 = load_splat(&bytes[..], LoadMode::Strict).unwrap().gaussians;
    let w1 = render_once(g1.clone(), 1);
    let w8 = render_once(g1, 8);
    assert_eq!(w1.fnv1a64(), w8.fnv1a64(), "digest drift across widths");
    assert_eq!(w1.data, w8.data, "byte drift across widths");
}

// ---------------------------------------------------------------------------
// Fixture zoo: checked-in files with known contents.

#[test]
fn minimal_fixtures_load_strict() {
    let a = load_splat(
        std::fs::File::open(fixture("minimal.splat")).unwrap(),
        LoadMode::Strict,
    )
    .unwrap();
    assert_eq!(a.report.kept, 4);
    assert_eq!(a.report.dropped.total(), 0);
    assert_all_well_formed(&a.gaussians, "minimal.splat");

    let f = std::fs::File::open(fixture("minimal.ply")).unwrap();
    let a = load_ply(std::io::BufReader::new(f), LoadMode::Strict).unwrap();
    assert_eq!(a.report.kept, 3);
    // The fixture's shuffled header carries 9 f_rest coefficients.
    assert_eq!(a.report.sh_rest_coeffs, 9);
    assert_all_well_formed(&a.gaussians, "minimal.ply");
}

#[test]
fn degenerate_splat_fixture_counters() {
    let bytes = std::fs::read(fixture("degenerate.splat")).unwrap();
    // Strict: the first bad record is record 1's NaN position.
    match load_splat(&bytes[..], LoadMode::Strict) {
        Err(AssetError::NonFinite { field: "position", index: 1 }) => {}
        other => panic!("wrong strict result: {other:?}"),
    }
    // Lossy: exact per-cause counters, well-formed survivors.
    let a = load_splat(&bytes[..], LoadMode::Lossy).unwrap();
    assert_eq!(a.report.kept, 3);
    assert_eq!(a.report.dropped.bad_position, 2);
    assert_eq!(a.report.dropped.bad_scale, 2);
    assert_eq!(a.report.dropped.bad_rotation, 1);
    assert_eq!(a.report.dropped.truncated_tail, 1);
    assert_eq!(a.report.dropped.total(), 6);
    assert_all_well_formed(&a.gaussians, "degenerate.splat survivors");
    // And the survivors render without tripping any projection guard.
    let img = render_once(a.gaussians, 2);
    assert!(img.data.iter().all(|p| p.iter().all(|c| c.is_finite())));
}

#[test]
fn degenerate_ply_fixture_counters() {
    let bytes = std::fs::read(fixture("degenerate.ply")).unwrap();
    match load_ply(&bytes[..], LoadMode::Strict) {
        Err(AssetError::NonFinite { field: "position", index: 1 }) => {}
        other => panic!("wrong strict result: {other:?}"),
    }
    let a = load_ply(&bytes[..], LoadMode::Lossy).unwrap();
    assert_eq!(a.report.kept, 1);
    assert_eq!(a.report.dropped.bad_position, 1);
    assert_eq!(a.report.dropped.bad_scale, 1);
    assert_eq!(a.report.dropped.bad_rotation, 1);
    assert_eq!(a.report.dropped.total(), 3);
    assert_all_well_formed(&a.gaussians, "degenerate.ply survivors");
}

#[test]
fn zoo_scenes_load_assemble_and_render_across_widths() {
    for (file, sh_rest) in [("zoo_room.splat", 0usize), ("zoo_room.ply", 9)] {
        let (scene, report) = load_scene(
            &fixture(file),
            LoadMode::Strict,
            &AssembleOptions::default(),
        )
        .unwrap();
        assert_eq!(report.kept, 516, "{file}");
        assert_eq!(report.dropped.total(), 0, "{file}");
        assert_eq!(report.sh_rest_coeffs, sh_rest, "{file}");
        assert_eq!(scene.name, "zoo_room");
        scene.tree.check_invariants().unwrap();
        assert!(scene.tree.len() > 516, "{file}: no interior nodes");

        let cam = scene.scenario_camera(0);
        let pipeline =
            FramePipeline::builder(scene).tau(16.0).subtree_size(32).build();
        let mut frames = Vec::new();
        for threads in [1usize, 8] {
            let backend = CpuBackend::with_threads(threads);
            let mut session =
                pipeline.session_on(&backend, pipeline.default_options());
            frames.push(session.render(&cam).expect("zoo render"));
        }
        assert_eq!(frames[0].data, frames[1].data, "{file}: width drift");
        let mean: f32 = frames[0]
            .data
            .iter()
            .map(|p| p[0] + p[1] + p[2])
            .sum::<f32>()
            / (frames[0].data.len() as f32 * 3.0);
        assert!(mean > 1e-3, "{file} rendered black (mean {mean})");
    }
}

#[test]
fn load_scene_sniffs_format_without_extension() {
    // A PLY copied to an extension-less path must still load via the
    // `ply` magic sniff.
    let bytes = std::fs::read(fixture("minimal.ply")).unwrap();
    let dir = std::env::temp_dir();
    let path = dir.join("sltarch_sniff_fixture");
    std::fs::write(&path, &bytes).unwrap();
    let (scene, report) =
        load_scene(&path, LoadMode::Strict, &AssembleOptions::default())
            .unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(report.kept, 3);
    assert_eq!(scene.name, "sltarch_sniff_fixture");
}

// ---------------------------------------------------------------------------
// Satellite 2: degenerate-input fuzzing.

#[test]
fn splat_fuzz_truncation_at_every_offset() {
    forall(8, |rng| {
        let n = 1 + rng.below(6);
        let g = random_batch(rng, n);
        let mut bytes = Vec::new();
        write_splat(&mut bytes, &g).unwrap();
        for cut in 0..=bytes.len() {
            let slice = &bytes[..cut];
            let whole = cut / SPLAT_RECORD_BYTES;
            let partial = cut % SPLAT_RECORD_BYTES != 0;
            match load_splat(slice, LoadMode::Strict) {
                Ok(a) => {
                    assert!(!partial, "cut {cut}");
                    assert_eq!(a.report.kept, whole);
                }
                Err(AssetError::Truncated { index, got }) => {
                    assert!(partial, "cut {cut}");
                    assert_eq!((index, got), (whole, cut % SPLAT_RECORD_BYTES));
                }
                Err(e) => panic!("cut {cut}: unexpected error {e}"),
            }
            let a = load_splat(slice, LoadMode::Lossy).unwrap();
            assert_eq!(a.report.kept, whole);
            assert_eq!(a.report.dropped.truncated_tail, u64::from(partial));
            assert_all_well_formed(&a.gaussians, "splat truncation fuzz");
        }
    });
}

#[test]
fn ply_fuzz_truncation_at_every_offset() {
    let mut rng = Rng::new(0x7D1);
    let g = random_batch(&mut rng, 3);
    let mut bytes = Vec::new();
    write_ply(&mut bytes, &g).unwrap();
    let body = bytes.len() - 3 * 14 * 4;
    for cut in 0..bytes.len() {
        let slice = &bytes[..cut];
        if cut < body {
            // Header cut: structural, both modes fail with a typed
            // error and never panic.
            for mode in [LoadMode::Strict, LoadMode::Lossy] {
                match load_ply(slice, mode) {
                    Err(
                        AssetError::BadHeader(_) | AssetError::BadMagic,
                    ) => {}
                    other => panic!("cut {cut} {mode:?}: {other:?}"),
                }
            }
        } else {
            // Body cut: strict names the truncated record, lossy keeps
            // the whole ones.
            let whole = (cut - body) / (14 * 4);
            let got = (cut - body) % (14 * 4);
            match load_ply(slice, LoadMode::Strict) {
                Err(AssetError::Truncated { index, got: g }) => {
                    assert_eq!((index, g), (whole, got), "cut {cut}");
                }
                other => panic!("cut {cut}: {other:?}"),
            }
            let a = load_ply(slice, LoadMode::Lossy).unwrap();
            assert_eq!(a.report.kept, whole, "cut {cut}");
            assert_eq!(a.report.dropped.truncated_tail, 1, "cut {cut}");
        }
    }
}

/// Canonical-encoder slot offsets (see `REQUIRED` in assets::ply).
const SLOT_X: usize = 0;
const SLOT_DC0: usize = 3;
const SLOT_OPACITY: usize = 6;
const SLOT_SCALE0: usize = 7;
const SLOT_ROT0: usize = 10;

fn ply_body_offset(bytes: &[u8]) -> usize {
    let needle = b"end_header\n";
    bytes
        .windows(needle.len())
        .position(|w| w == needle)
        .expect("encoder output has a header")
        + needle.len()
}

fn poison(bytes: &mut [u8], vertex: usize, slot: usize, value: f32) {
    let body = ply_body_offset(bytes);
    let off = body + vertex * 14 * 4 + slot * 4;
    bytes[off..off + 4].copy_from_slice(&value.to_le_bytes());
}

#[test]
fn ply_fuzz_nonfinite_fields_are_typed_and_dropped() {
    // (slot, poison value, strict field name; None => ZeroNormQuat).
    let cases: [(usize, f32, Option<&str>); 7] = [
        (SLOT_X, f32::NAN, Some("position")),
        (SLOT_X, f32::INFINITY, Some("position")),
        (SLOT_SCALE0, f32::NAN, Some("scale")),
        (SLOT_SCALE0, f32::INFINITY, Some("scale")), // exp(inf) = inf
        (SLOT_DC0, f32::NAN, Some("color")),
        (SLOT_OPACITY, f32::NAN, Some("opacity")),
        (SLOT_ROT0, f32::NAN, Some("rotation")),
    ];
    forall(8, |rng| {
        let n = 2 + rng.below(6);
        let g = random_batch(rng, n);
        let victim = rng.below(n);
        for (slot, value, field) in cases {
            let mut bytes = Vec::new();
            write_ply(&mut bytes, &g).unwrap();
            poison(&mut bytes, victim, slot, value);
            match load_ply(&bytes[..], LoadMode::Strict) {
                Err(AssetError::NonFinite { field: f, index }) => {
                    assert_eq!(Some(f), field, "slot {slot}");
                    assert_eq!(index, victim, "slot {slot}");
                }
                other => panic!("slot {slot}: {other:?}"),
            }
            let a = load_ply(&bytes[..], LoadMode::Lossy).unwrap();
            assert_eq!(a.report.kept, n - 1, "slot {slot}");
            assert_eq!(a.report.dropped.total(), 1, "slot {slot}");
            assert_all_well_formed(&a.gaussians, "poison fuzz");
        }
        // Zero-norm quaternion: its own typed variant.
        let mut bytes = Vec::new();
        write_ply(&mut bytes, &g).unwrap();
        for k in 0..4 {
            poison(&mut bytes, victim, SLOT_ROT0 + k, 0.0);
        }
        match load_ply(&bytes[..], LoadMode::Strict) {
            Err(AssetError::ZeroNormQuat { index }) => {
                assert_eq!(index, victim)
            }
            other => panic!("zero quat: {other:?}"),
        }
        let a = load_ply(&bytes[..], LoadMode::Lossy).unwrap();
        assert_eq!(a.report.kept, n - 1);
        assert_eq!(a.report.dropped.bad_rotation, 1);
    });
}

#[test]
fn ply_fuzz_shuffled_headers_load_identically() {
    // Any permutation of the vertex properties (plus injected unknown
    // scalar properties) must load to the identical batch.
    let names = [
        "x", "y", "z", "f_dc_0", "f_dc_1", "f_dc_2", "opacity", "scale_0",
        "scale_1", "scale_2", "rot_0", "rot_1", "rot_2", "rot_3",
    ];
    forall(16, |rng| {
        let n = 1 + rng.below(8);
        let g = random_batch(rng, n);
        let mut canonical = Vec::new();
        write_ply(&mut canonical, &g).unwrap();
        let want =
            load_ply(&canonical[..], LoadMode::Strict).unwrap().gaussians;
        let body = ply_body_offset(&canonical);

        // Shuffle the slots, sprinkle unknown properties in between.
        let mut order: Vec<usize> = (0..14).collect();
        rng.shuffle(&mut order);
        let junk_before: Vec<bool> =
            (0..14).map(|_| rng.below(4) == 0).collect();

        let mut header = String::from(
            "ply\nformat binary_little_endian 1.0\ncomment fuzz\n",
        );
        header.push_str(&format!("element vertex {}\n", g.len()));
        for (pos, &slot) in order.iter().enumerate() {
            if junk_before[pos] {
                header.push_str(&format!("property uint junk_{pos}\n"));
            }
            header.push_str(&format!("property float {}\n", names[slot]));
        }
        header.push_str("end_header\n");
        let mut bytes = header.into_bytes();
        for v in 0..g.len() {
            for (pos, &slot) in order.iter().enumerate() {
                if junk_before[pos] {
                    bytes.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
                }
                let off = body + v * 14 * 4 + slot * 4;
                bytes.extend_from_slice(&canonical[off..off + 4]);
            }
        }
        let got = load_ply(&bytes[..], LoadMode::Strict).unwrap().gaussians;
        assert_batches_bitwise_equal(&got, &want, "shuffled header");
    });
}

#[test]
fn ply_absurd_vertex_count_is_typed_in_both_modes() {
    let header = b"ply\nformat binary_little_endian 1.0\n\
                   element vertex 100000001\nproperty float x\nend_header\n";
    for mode in [LoadMode::Strict, LoadMode::Lossy] {
        match load_ply(&header[..], mode) {
            Err(AssetError::AbsurdVertexCount { count: 100_000_001 }) => {}
            other => panic!("{mode:?}: {other:?}"),
        }
    }
}

#[test]
fn fuzz_random_bytes_never_panic() {
    forall(96, |rng| {
        let len = rng.below(600);
        let mut blob: Vec<u8> =
            (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        for mode in [LoadMode::Strict, LoadMode::Lossy] {
            // Whatever the result, it must be a Result — never a panic.
            let _ = load_splat(&blob[..], mode);
            let _ = load_ply(&blob[..], mode);
            if let Ok(a) = load_splat(&blob[..], LoadMode::Lossy) {
                assert_all_well_formed(&a.gaussians, "random splat blob");
            }
        }
        // Same blob behind a valid PLY header: a syntactically fine
        // header over garbage vertex data.
        let mut framed = b"ply\nformat binary_little_endian 1.0\n\
                           element vertex 7\n"
            .to_vec();
        for name in [
            "x", "y", "z", "f_dc_0", "f_dc_1", "f_dc_2", "opacity",
            "scale_0", "scale_1", "scale_2", "rot_0", "rot_1", "rot_2",
            "rot_3",
        ] {
            framed.extend_from_slice(
                format!("property float {name}\n").as_bytes(),
            );
        }
        framed.extend_from_slice(b"end_header\n");
        framed.append(&mut blob);
        let _ = load_ply(&framed[..], LoadMode::Strict);
        let a = load_ply(&framed[..], LoadMode::Lossy).unwrap();
        assert_all_well_formed(&a.gaussians, "framed garbage");
    });
}

#[test]
fn empty_batch_cannot_assemble() {
    assert!(matches!(
        assemble_scene(Gaussians::default(), &AssembleOptions::default()),
        Err(AssetError::EmptyScene)
    ));
    // And an I/O-level miss is typed, not a panic.
    match load_scene(
        Path::new("/nonexistent/sltarch/scene.splat"),
        LoadMode::Strict,
        &AssembleOptions::default(),
    ) {
        Err(AssetError::Io(_)) => {}
        other => panic!("wrong result: {:?}", other.map(|_| ())),
    }
}
