//! Golden-frame regression harness: renders five fixed scenes
//! (quickstart, city orbit, VR walkthrough frame, and the two
//! checked-in fixture-zoo assets under `tests/fixtures/`) and compares the
//! FNV-1a digests of their quantized RGBA buffers against the
//! checked-in values in `tests/golden_digests.txt`, so any future
//! pipeline change that silently alters rendered output fails tier-1.
//!
//! Every scene is rendered at scheduler widths {1, 2, 8} and the
//! images must be byte-identical across widths before the digest is
//! even checked — the parallel front end and tile scheduler may never
//! change pixels. The SoA blend kernel (`BlendKernel::Soa`) is held to
//! the same bar: per alpha mode, widths {1, 8}, byte-identical to the
//! scalar-kernel frame. So is out-of-core slab residency: a managed
//! session under an eviction-heavy budget must render the exact golden
//! frame.
//!
//! To update the digests after an *intended* output change:
//! `SLTARCH_BLESS=1 cargo test --test golden` and commit the file.
//! Digests for scenes missing from the file are bootstrapped (written
//! and reported, not failed) so a fresh harness run can pin them.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use sltarch::assets::{load_scene, AssembleOptions, LoadMode};
use sltarch::config::SceneConfig;
use sltarch::coordinator::renderer::AlphaMode;
use sltarch::coordinator::{
    BatchConfig, BlendKernel, CpuBackend, FramePipeline, RenderOptions,
};
use sltarch::math::{Camera, Vec3};
use sltarch::residency::ResidencyConfig;
use sltarch::scene::{orbit_cameras, walkthrough};

fn digest_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden_digests.txt")
}

/// The five pinned scenes: name, pipeline, camera.
fn scenes() -> Vec<(&'static str, FramePipeline, Camera)> {
    let mut out = Vec::new();

    // 1. The quickstart example's frame (small indoor scene).
    let cfg = SceneConfig::small_scale().quick();
    let pipeline = FramePipeline::builder(cfg.build(42))
        .tau(16.0)
        .subtree_size(32)
        .build();
    let cam = pipeline.scene().scenario_camera(0);
    out.push(("quickstart", pipeline, cam));

    // 2. A city orbit frame (large-scale scene, mid-orbit camera).
    let cfg = SceneConfig::large_scale().quick();
    let cam = orbit_cameras(cfg.extent, 0.9, 12, 256, 256)[4];
    let pipeline = FramePipeline::builder(cfg.build(7)).tau(16.0).build();
    out.push(("city_orbit", pipeline, cam));

    // 3. A VR walkthrough frame (terrain scene, walkthrough path).
    let cfg = SceneConfig::terrain().quick();
    let cam = walkthrough(cfg.extent, 8, 256, 256)[2];
    let pipeline = FramePipeline::builder(cfg.build(11)).tau(16.0).build();
    out.push(("vr_walkthrough", pipeline, cam));

    // 4 + 5. The checked-in fixture zoo, one scene per asset format —
    // pins the whole ingestion path (parse -> assemble -> render), so a
    // parser change that alters any decoded field fails tier-1 exactly
    // like a renderer change would.
    for (file, name) in
        [("zoo_room.splat", "fixture_splat"), ("zoo_room.ply", "fixture_ply")]
    {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("tests/fixtures")
            .join(file);
        let (scene, report) =
            load_scene(&path, LoadMode::Strict, &AssembleOptions::default())
                .expect("fixture zoo scene must load strictly");
        assert_eq!(
            report.dropped.total(),
            0,
            "{file}: zoo fixtures are fully well-formed"
        );
        let cam = scene.scenario_camera(0);
        let pipeline =
            FramePipeline::builder(scene).tau(16.0).subtree_size(32).build();
        out.push((name, pipeline, cam));
    }

    out
}

fn read_digests(path: &Path) -> BTreeMap<String, u64> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return BTreeMap::new();
    };
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        if let (Some(name), Some(hex)) = (it.next(), it.next()) {
            if let Ok(v) = u64::from_str_radix(hex, 16) {
                out.insert(name.to_string(), v);
            }
        }
    }
    out
}

/// Best-effort rewrite of the digest file (a read-only checkout only
/// degrades bootstrap/bless to a warning — the equivalence assertions
/// above have already run either way).
fn write_digests(path: &Path, digests: &BTreeMap<String, u64>) {
    let mut text = String::from(
        "# Golden-frame digests: FNV-1a(64) over each scene's quantized\n\
         # RGBA buffer (see rust/tests/golden.rs). Regenerate after an\n\
         # INTENDED output change with:\n\
         #   SLTARCH_BLESS=1 cargo test --test golden\n",
    );
    for (name, v) in digests {
        writeln!(text, "{name} {v:016x}").unwrap();
    }
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("golden: could not write {}: {e}", path.display());
    }
}

#[test]
fn golden_frames_match_checked_in_digests() {
    let path = digest_path();
    let checked = read_digests(&path);
    let mut computed = BTreeMap::new();

    for (name, pipeline, cam) in scenes() {
        // Byte-identity across scheduler widths comes first: the
        // parallel front end / tile scheduler may never change pixels.
        let mut images = Vec::new();
        for threads in [1usize, 2, 8] {
            let backend = CpuBackend::with_threads(threads);
            let mut session =
                pipeline.session_on(&backend, pipeline.default_options());
            let img = session.render(&cam).expect("golden render");
            assert_eq!(session.stats().front_end_threads, threads, "{name}");
            images.push(img);
        }
        for (img, threads) in images.iter().zip([1usize, 2, 8]).skip(1) {
            assert_eq!(
                images[0].data, img.data,
                "scene `{name}`: width {threads} diverged from serial"
            );
        }

        // The SoA blend kernel may never change pixels either: for both
        // alpha dataflows, a kernel=Soa render at widths {1, 8} must be
        // byte-identical to the scalar-kernel frame.
        for alpha in [AlphaMode::Group, AlphaMode::Pixel] {
            let scalar_opts = RenderOptions {
                alpha,
                kernel: BlendKernel::Scalar,
                ..pipeline.default_options()
            };
            let backend = CpuBackend::with_threads(1);
            let mut session = pipeline.session_on(&backend, scalar_opts);
            let want = session.render(&cam).expect("scalar render");
            for threads in [1usize, 8] {
                let backend = CpuBackend::with_threads(threads);
                let mut session = pipeline.session_on(
                    &backend,
                    RenderOptions { kernel: BlendKernel::Soa, ..scalar_opts },
                );
                let img = session.render(&cam).expect("soa render");
                assert_eq!(
                    want.data, img.data,
                    "scene `{name}` ({alpha:?}): SoA kernel at width \
                     {threads} diverged from the scalar kernel"
                );
            }
        }

        // Slab residency may never change pixels: a managed session
        // under a budget tight enough to evict every frame must render
        // the exact golden frame (the manager only replays the search's
        // slab-access trace — it sits after the search by construction).
        {
            let slt = pipeline.sltree();
            let budget = 3 * slt.subtrees[slt.top as usize].bytes().max(1);
            let backend = CpuBackend::with_threads(2);
            let mut session = pipeline.session_on(
                &backend,
                RenderOptions {
                    residency: ResidencyConfig::with_budget(budget),
                    ..pipeline.default_options()
                },
            );
            let img = session.render(&cam).expect("residency render");
            assert_eq!(
                images[0].data, img.data,
                "scene `{name}`: residency-managed render diverged"
            );
            let rs = session.stats().residency;
            assert_eq!(rs.frames, 1, "{name}: residency frame not charged");
            assert!(rs.misses > 0, "{name}: tight budget must demand-fault");
        }

        let img = &images[0];
        let mean: f32 = img.data.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>()
            / (img.data.len() as f32 * 3.0);
        assert!(mean > 1e-3, "scene `{name}` rendered black (mean {mean})");
        computed.insert(name.to_string(), img.fnv1a64());
    }

    let bless = std::env::var("SLTARCH_BLESS").is_ok();
    if !bless {
        // Verify the pinned scenes BEFORE any bootstrap rewrite, so a
        // drifted frame can never silently re-bless itself.
        for (name, &got) in &computed {
            if let Some(&want) = checked.get(name) {
                assert_eq!(
                    got, want,
                    "scene `{name}`: digest {got:016x} != checked-in \
                     {want:016x}. If this output change is intended, \
                     re-bless with `SLTARCH_BLESS=1 cargo test --test \
                     golden` and commit tests/golden_digests.txt"
                );
            }
        }
    }

    let missing =
        computed.keys().filter(|k| !checked.contains_key(*k)).count();
    if bless || missing > 0 {
        write_digests(&path, &computed);
        if !bless {
            eprintln!(
                "golden: bootstrapped {missing} digest(s) into {} — commit \
                 the file to pin them",
                path.display()
            );
        }
    }
}

/// Shift a camera's eye by `offset` world units keeping orientation and
/// intrinsics exactly: for a view `V(x) = R x + t`, `t' = t - R d`.
fn offset_camera(cam: &Camera, offset: Vec3) -> Camera {
    let mut out = *cam;
    let r = cam.view.rotation();
    for i in 0..3 {
        out.view.m[i][3] -= r.row(i).dot(offset);
    }
    out
}

#[test]
fn golden_stereo_batch_matches_single_view_renders() {
    // The PR-10 batch-rendering bar over the same pinned scenes: a
    // stereo pair (each scene's golden camera plus a 6.5 cm-offset
    // right eye) rendered through a ViewBatch must be byte-identical to
    // two independent session renders at scheduler widths {1, 2, 8} —
    // with every sharing level on AND with all sharing off. The left
    // eye is the golden camera itself, so the batch path is transitively
    // pinned to the checked-in digests through the per-view equality.
    for (name, pipeline, cam) in scenes() {
        let right = offset_camera(&cam, Vec3::new(0.065, 0.0, 0.0));
        let cams = [cam, right];
        for threads in [1usize, 2, 8] {
            let backend = CpuBackend::with_threads(threads);
            for cfg in [BatchConfig::default(), BatchConfig::independent()] {
                let mut batch =
                    pipeline.batch_on(&backend, pipeline.default_options(), cfg);
                let imgs = batch.render(&cams).expect("stereo batch render");
                for (v, eye_cam) in cams.iter().enumerate() {
                    let mut session =
                        pipeline.session_on(&backend, pipeline.default_options());
                    let want = session.render(eye_cam).expect("single-view render");
                    assert_eq!(
                        imgs[v].data, want.data,
                        "scene `{name}` eye {v}: batch at width {threads} \
                         diverged from the single-view render ({cfg:?})"
                    );
                }
            }
        }
        // The duplicate-feed case (both eyes bitwise equal) coalesces
        // to one front end and must still reproduce the golden frame.
        let mut batch = pipeline.batch();
        let imgs = batch.render(&[cam, cam]).expect("duplicate batch render");
        let mut session = pipeline.session();
        let want = session.render(&cam).expect("single-view render");
        assert_eq!(imgs[0].data, want.data, "scene `{name}`: left dup eye");
        assert_eq!(imgs[1].data, want.data, "scene `{name}`: right dup eye");
        assert_eq!(
            batch.batch_stats().front_ends_shared,
            1,
            "scene `{name}`: bitwise-equal eyes must coalesce"
        );
    }
}
