//! End-to-end integration over the full L3 stack (CPU path): scene ->
//! SLTree -> frame pipeline -> sessions -> image + simulation, plus
//! experiment smoke runs.

use sltarch::config::{RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer};
use sltarch::coordinator::{CpuBackend, FramePipeline, RenderOptions, RenderStats};
use sltarch::metrics::psnr;
use sltarch::sim::HwVariant;

fn quick_pipeline(seed: u64) -> FramePipeline {
    FramePipeline::builder(SceneConfig::small_scale().quick().build(seed)).build()
}

#[test]
fn render_every_scenario_produces_stable_images() {
    let p = quick_pipeline(31);
    let mut session = p.session();
    for i in 0..6 {
        let cam = p.scene().scenario_camera(i);
        let a = session.render(&cam).unwrap();
        // Determinism: bit-identical across runs and across sessions
        // (one long-lived session vs a fresh one per frame).
        let b = p.session().render(&cam).unwrap();
        assert_eq!(a.data, b.data, "scenario {i} not deterministic");
        let mean: f32 =
            a.data.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>() / a.data.len() as f32;
        assert!(mean > 0.005, "scenario {i} black image");
    }
    assert_eq!(session.stats().frames, 6);
}

#[test]
fn parallel_tile_scheduler_is_bit_identical_across_thread_counts() {
    let p = quick_pipeline(34);
    for (cam_i, mode) in [(0, AlphaMode::Group), (3, AlphaMode::Pixel)] {
        let cam = p.scene().scenario_camera(cam_i);
        let cut = p.search(&cam);
        let queue = p.scene().gaussians.gather(&cut);
        let serial = CpuRenderer::render_serial(&queue, &cam, mode, p.rcfg());
        for threads in [1usize, 2, 8] {
            let backend = CpuBackend::with_threads(threads);
            let mut session = backend_session(&p, &backend, mode);
            let par = session.render(&cam).unwrap();
            assert_eq!(
                serial.data, par.data,
                "scenario {cam_i} {mode:?} diverged at {threads} threads"
            );
        }
    }
}

fn backend_session<'p>(
    p: &'p FramePipeline,
    backend: &'p CpuBackend,
    alpha: AlphaMode,
) -> sltarch::coordinator::RenderSession<'p> {
    p.session_on(backend, RenderOptions { alpha, ..p.default_options() })
}

#[test]
fn session_stats_match_legacy_report_counters() {
    // The unified RenderStats must agree with the old PathReport
    // arithmetic: frames, cut_total and pairs_total recomputed from the
    // seed per-frame path, and the per-stage timings must sum to no
    // more than the recorded wall time.
    let p = quick_pipeline(35);
    let cams: Vec<_> = (0..3).map(|i| p.scene().scenario_camera(i)).collect();
    let mut session = p.session();
    let images = session.render_path(&cams).unwrap();
    let stats: RenderStats = *session.stats();

    let mut cut_total = 0u64;
    let mut pairs_total = 0u64;
    let mut scratch = sltarch::coordinator::FrameScratch::new();
    for (img, cam) in images.iter().zip(cams.iter()) {
        let cut = p.search(cam);
        cut_total += cut.len() as u64;
        let queue = p.scene().gaussians.gather(&cut);
        let want =
            CpuRenderer::render_with_scratch(&queue, cam, AlphaMode::Group, p.rcfg(), 4, &mut scratch);
        pairs_total += scratch.bins.pairs;
        assert_eq!(img.data, want.data, "session diverged from the seed path");
    }
    assert_eq!(stats.frames, cams.len());
    assert_eq!(stats.cut_total, cut_total);
    assert_eq!(stats.pairs_total, pairs_total);
    assert!(stats.wall_seconds > 0.0);
    assert!(
        stats.stages.staged_total() <= stats.wall_seconds + 1e-9,
        "stage sum {} > wall {}",
        stats.stages.staged_total(),
        stats.wall_seconds
    );
    // Every stage actually ran and was timed.
    for (name, secs) in stats.stages.rows() {
        assert!(secs >= 0.0, "stage {name} negative: {secs}");
    }
    assert!(stats.stages.blend > 0.0, "blend stage untimed");
    assert!(stats.fps() > 0.0);
}

#[test]
fn concurrent_sessions_share_one_pipeline() {
    // The multi-client serving contract: N sessions over one
    // &FramePipeline from separate threads, bit-identical to serial use.
    let p = quick_pipeline(36);
    let reference: Vec<_> = (0..4)
        .map(|i| p.session().render(&p.scene().scenario_camera(i)).unwrap())
        .collect();
    let rendered: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let p = &p;
                s.spawn(move || {
                    let mut session = p.session();
                    session.render(&p.scene().scenario_camera(i)).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, (a, b)) in reference.iter().zip(rendered.iter()).enumerate() {
        assert_eq!(a.data, b.data, "client {i} diverged under concurrency");
    }
}

#[test]
fn concurrent_parallel_front_end_matches_sequential_sessions() {
    // PR 3 extension of the multi-client contract: N concurrent
    // sessions with the *parallel front end* enabled (scheduler width
    // 8 -> chunked projection, per-worker-histogram binning, parallel
    // tile sort all spawn inside each session) must produce exactly
    // the images N sequential serial-width sessions produce.
    let p = quick_pipeline(37);
    let serial = CpuBackend::with_threads(1);
    let wide = CpuBackend::with_threads(8);
    let sequential: Vec<_> = (0..4)
        .map(|i| {
            p.session_on(&serial, p.default_options())
                .render(&p.scene().scenario_camera(i))
                .unwrap()
        })
        .collect();
    let concurrent: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (p, wide) = (&p, &wide);
                s.spawn(move || {
                    let mut session = p.session_on(wide, p.default_options());
                    let img =
                        session.render(&p.scene().scenario_camera(i)).unwrap();
                    assert_eq!(session.stats().front_end_threads, 8);
                    assert_eq!(session.stats().threads, 8);
                    img
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, (a, b)) in sequential.iter().zip(concurrent.iter()).enumerate() {
        assert_eq!(
            a.data, b.data,
            "client {i} diverged with the concurrent parallel front end"
        );
    }
}

#[test]
fn cut_cache_camera_jump_falls_back_and_stays_correct() {
    use sltarch::lod::CutCacheConfig;
    use sltarch::scene::orbit_cameras;
    let p = quick_pipeline(38);
    // Frames 0..=3: a slow orbit (~1.1 world units / ~0.2 rad between
    // frames). Frame 4 teleports across the scene; frame 5 holds still.
    let mut cams: Vec<_> =
        orbit_cameras(6.0, 0.9, 32, 256, 256).into_iter().take(4).collect();
    cams.push(p.scene().scenario_camera(5));
    cams.push(p.scene().scenario_camera(5));
    let jumpy = RenderOptions {
        cut_cache: CutCacheConfig { max_translation: 2.0, ..Default::default() },
        ..p.default_options()
    };
    let mut session = p.session_with(jumpy);
    let images = session.render_path(&cams).unwrap();
    let stats = *session.stats();
    // cold, hit, hit, hit, cold (teleport beyond max_translation), hit.
    assert_eq!(stats.cache_hit, 4, "jump fallback pattern wrong");
    assert!(stats.revalidated > 0);
    // Every frame — before, across and after the fallback — must equal
    // a cache-disabled render bit-for-bit.
    let mut cold = p.session_with(RenderOptions {
        cut_cache: CutCacheConfig::disabled(),
        ..p.default_options()
    });
    let want = cold.render_path(&cams).unwrap();
    assert_eq!(cold.stats().cache_hit, 0);
    assert_eq!(cold.stats().revalidated, 0);
    for (i, (a, b)) in images.iter().zip(want.iter()).enumerate() {
        assert_eq!(a.data, b.data, "frame {i} diverged around the fallback");
    }
}

#[test]
fn simulation_is_deterministic_across_runs() {
    let p = quick_pipeline(32);
    let cam = p.scene().scenario_camera(2);
    let a = p.simulate(&cam, &HwVariant::fig9());
    let b = p.simulate(&cam, &HwVariant::fig9());
    for (x, y) in a.sims.iter().zip(b.sims.iter()) {
        assert_eq!(x.report.lod.cycles, y.report.lod.cycles);
        assert_eq!(x.report.splat.cycles, y.report.splat.cycles);
    }
}

#[test]
fn subtree_size_sweep_preserves_results_and_shifts_cost() {
    // The cut is invariant under tau_s; the traversal cost profile moves.
    let scene = SceneConfig::small_scale().quick().build(33);
    let mut cuts = Vec::new();
    for tau_s in [8u32, 32, 128] {
        let p = FramePipeline::builder(scene.clone()).subtree_size(tau_s).build();
        let cam = p.scene().scenario_camera(1);
        cuts.push(p.search(&cam));
    }
    assert_eq!(cuts[0], cuts[1]);
    assert_eq!(cuts[1], cuts[2]);
}

#[test]
fn lod_tau_controls_quality_cost_tradeoff() {
    let scene = SceneConfig::small_scale().quick().build(34);
    let p = FramePipeline::builder(scene)
        .render_config(RenderConfig::default())
        .build();
    let cam = p.scene().scenario_camera(3);
    let render = |tau: f32| {
        let cut_len = p.search_with_tau(&cam, tau).len();
        let mut session = p.session_with(RenderOptions {
            alpha: AlphaMode::Pixel,
            lod_tau: tau,
            ..p.default_options()
        });
        (cut_len, session.render(&cam).unwrap())
    };
    let (n_fine, img_fine) = render(2.0);
    let (n_mid, img_mid) = render(16.0);
    let (n_coarse, img_coarse) = render(64.0);
    assert!(n_fine > n_mid && n_mid > n_coarse,
        "cut must shrink with tau: {n_fine} {n_mid} {n_coarse}");
    // Quality degrades monotonically-ish with coarseness.
    let p_mid = psnr(&img_fine, &img_mid);
    let p_coarse = psnr(&img_fine, &img_coarse);
    assert!(p_mid > p_coarse, "psnr: mid {p_mid} coarse {p_coarse}");
}

#[test]
fn experiments_smoke_quick() {
    // Every registered experiment must run to completion in quick mode.
    for name in sltarch::experiments::ALL {
        assert!(
            sltarch::experiments::run_by_name(name, true),
            "experiment {name} failed to run"
        );
    }
}
