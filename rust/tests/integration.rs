//! End-to-end integration over the full L3 stack (CPU path): scene ->
//! SLTree -> frame pipeline -> image + simulation, plus experiment
//! smoke runs.

use sltarch::config::{ArchConfig, RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer};
use sltarch::coordinator::FramePipeline;
use sltarch::metrics::psnr;
use sltarch::sim::HwVariant;

fn quick_pipeline(seed: u64) -> FramePipeline {
    FramePipeline::new(
        SceneConfig::small_scale().quick().build(seed),
        RenderConfig::default(),
        ArchConfig::default(),
    )
}

#[test]
fn render_every_scenario_produces_stable_images() {
    let p = quick_pipeline(31);
    for i in 0..6 {
        let cam = p.scene.scenario_camera(i);
        let a = p.render(&cam, AlphaMode::Group).unwrap();
        let b = p.render(&cam, AlphaMode::Group).unwrap();
        // Determinism: bit-identical across runs.
        assert_eq!(a.data, b.data, "scenario {i} not deterministic");
        let mean: f32 =
            a.data.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>() / a.data.len() as f32;
        assert!(mean > 0.005, "scenario {i} black image");
    }
}

#[test]
fn parallel_tile_scheduler_is_bit_identical_across_thread_counts() {
    let p = quick_pipeline(34);
    for (cam_i, mode) in [(0, AlphaMode::Group), (3, AlphaMode::Pixel)] {
        let cam = p.scene.scenario_camera(cam_i);
        let cut = p.search(&cam);
        let queue = p.scene.gaussians.gather(&cut);
        let serial = CpuRenderer::render_serial(&queue, &cam, mode, &p.rcfg);
        for threads in [1usize, 2, 8] {
            let par = CpuRenderer::render_threaded(&queue, &cam, mode, &p.rcfg, threads);
            assert_eq!(
                serial.data, par.data,
                "scenario {cam_i} {mode:?} diverged at {threads} threads"
            );
        }
    }
}

#[test]
fn simulation_is_deterministic_across_runs() {
    let p = quick_pipeline(32);
    let cam = p.scene.scenario_camera(2);
    let a = p.simulate(&cam, &HwVariant::fig9());
    let b = p.simulate(&cam, &HwVariant::fig9());
    for (x, y) in a.sims.iter().zip(b.sims.iter()) {
        assert_eq!(x.report.lod.cycles, y.report.lod.cycles);
        assert_eq!(x.report.splat.cycles, y.report.splat.cycles);
    }
}

#[test]
fn subtree_size_sweep_preserves_results_and_shifts_cost() {
    // The cut is invariant under tau_s; the traversal cost profile moves.
    let scene = SceneConfig::small_scale().quick().build(33);
    let arch = ArchConfig::default();
    let mut cuts = Vec::new();
    for tau_s in [8u32, 32, 128] {
        let rcfg = RenderConfig { subtree_size: tau_s, ..Default::default() };
        let p = FramePipeline::new(scene.clone(), rcfg, arch);
        let cam = p.scene.scenario_camera(1);
        cuts.push(p.search(&cam));
    }
    assert_eq!(cuts[0], cuts[1]);
    assert_eq!(cuts[1], cuts[2]);
}

#[test]
fn lod_tau_controls_quality_cost_tradeoff() {
    let scene = SceneConfig::small_scale().quick().build(34);
    let arch = ArchConfig::default();
    let cam_idx = 3;
    let render = |tau: f32| {
        let rcfg = RenderConfig { lod_tau: tau, ..Default::default() };
        let p = FramePipeline::new(scene.clone(), rcfg, arch);
        let cam = p.scene.scenario_camera(cam_idx);
        let cut_len = p.search(&cam).len();
        (cut_len, p.render(&cam, AlphaMode::Pixel).unwrap())
    };
    let (n_fine, img_fine) = render(2.0);
    let (n_mid, img_mid) = render(16.0);
    let (n_coarse, img_coarse) = render(64.0);
    assert!(n_fine > n_mid && n_mid > n_coarse,
        "cut must shrink with tau: {n_fine} {n_mid} {n_coarse}");
    // Quality degrades monotonically-ish with coarseness.
    let p_mid = psnr(&img_fine, &img_mid);
    let p_coarse = psnr(&img_fine, &img_coarse);
    assert!(p_mid > p_coarse, "psnr: mid {p_mid} coarse {p_coarse}");
}

#[test]
fn experiments_smoke_quick() {
    // Every registered experiment must run to completion in quick mode.
    for name in sltarch::experiments::ALL {
        assert!(
            sltarch::experiments::run_by_name(name, true),
            "experiment {name} failed to run"
        );
    }
}
