"""Kernel-vs-reference correctness: the CORE build-time signal.

The Pallas kernels (interpret=True) must agree with the pure-jnp oracles
in ``compile.kernels.ref`` for every shape/value regime the rust runtime
can feed them. hypothesis sweeps the value space; fixed tests pin the
regimes the paper cares about (padding rows, saturated tiles, group-vs-
pixel divergence behaviour).
"""

from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.project import BLOCK_N, project_pallas
from compile.kernels.splat import K_CHUNK, PIXELS, splat_tile_pallas

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- helpers

def rand_gaussians3d(rng, n):
    means = rng.uniform(-5.0, 5.0, (n, 3)).astype(np.float32)
    scales = rng.uniform(0.05, 1.5, (n, 3)).astype(np.float32)
    quats = rng.normal(0.0, 1.0, (n, 4)).astype(np.float32)
    # Avoid the degenerate zero quaternion.
    quats[np.abs(quats).sum(axis=1) < 1e-3] = np.array(
        [1, 0, 0, 0], dtype=np.float32
    )
    return means, scales, quats


def lookat_viewmat(eye, target=(0.0, 0.0, 0.0), up=(0.0, 1.0, 0.0)):
    eye = np.asarray(eye, dtype=np.float32)
    target = np.asarray(target, dtype=np.float32)
    up = np.asarray(up, dtype=np.float32)
    fwd = target - eye
    fwd = fwd / np.linalg.norm(fwd)
    right = np.cross(fwd, up)
    right = right / np.linalg.norm(right)
    true_up = np.cross(right, fwd)
    # Camera looks down +z in our convention.
    R = np.stack([right, true_up, fwd])
    t = -R @ eye
    view = np.eye(4, dtype=np.float32)
    view[:3, :3] = R
    view[:3, 3] = t
    return view


INTR = np.array([300.0, 300.0, 128.0, 128.0], dtype=np.float32)


def rand_splat_inputs(rng, k=K_CHUNK, origin=(96.0, 96.0), spread=40.0):
    mean2d = (
        np.asarray(origin, dtype=np.float32)
        + rng.uniform(-spread, spread + 16.0, (k, 2)).astype(np.float32)
    )
    # Random SPD conics: conic = M^T M + eps*I packed as (a,b,c).
    m = rng.normal(0.0, 0.6, (k, 2, 2)).astype(np.float32)
    spd = np.einsum("kji,kjl->kil", m, m) + 1e-3 * np.eye(2, dtype=np.float32)
    conic = np.stack([spd[:, 0, 0], spd[:, 0, 1], spd[:, 1, 1]], axis=-1)
    color = rng.uniform(0.0, 1.0, (k, 3)).astype(np.float32)
    opacity = rng.uniform(0.0, 1.0, k).astype(np.float32)
    return mean2d, conic.astype(np.float32), color, opacity


def run_both_splat(mean2d, conic, color, opacity, origin, rgb_in, t_in, mode):
    got = splat_tile_pallas(
        jnp.asarray(mean2d), jnp.asarray(conic), jnp.asarray(color),
        jnp.asarray(opacity), jnp.asarray(origin), jnp.asarray(rgb_in),
        jnp.asarray(t_in), alpha_mode=mode,
    )
    want = ref.splat_tile_ref(
        jnp.asarray(mean2d), jnp.asarray(conic), jnp.asarray(color),
        jnp.asarray(opacity), jnp.asarray(origin), jnp.asarray(rgb_in),
        jnp.asarray(t_in), alpha_mode=mode,
    )
    return got, want


# ------------------------------------------------------------- projection

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_project_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n = BLOCK_N * 4
    means, scales, quats = rand_gaussians3d(rng, n)
    view = lookat_viewmat((0.0, 0.0, -12.0))
    got = project_pallas(
        jnp.asarray(means), jnp.asarray(scales), jnp.asarray(quats),
        jnp.asarray(view), jnp.asarray(INTR),
    )
    want = ref.project_ref(
        jnp.asarray(means), jnp.asarray(scales), jnp.asarray(quats),
        jnp.asarray(view), jnp.asarray(INTR),
    )
    for g, w, name in zip(got, want, ["mean2d", "conic", "depth", "radius"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4,
            err_msg=f"projection output {name} mismatch",
        )


def test_project_culls_behind_camera():
    rng = np.random.default_rng(7)
    n = BLOCK_N
    means, scales, quats = rand_gaussians3d(rng, n)
    # Camera at origin looking at +z; half the points behind it.
    means[: n // 2, 2] = -np.abs(means[: n // 2, 2]) - 1.0
    means[n // 2:, 2] = np.abs(means[n // 2:, 2]) + 1.0
    view = np.eye(4, dtype=np.float32)
    _, _, depth, radius = project_pallas(
        jnp.asarray(means), jnp.asarray(scales), jnp.asarray(quats),
        jnp.asarray(view), jnp.asarray(INTR),
    )
    depth = np.asarray(depth)
    radius = np.asarray(radius)
    assert (radius[depth <= 0.2] == 0).all(), "behind-camera must be culled"
    assert (radius[depth > 0.2] > 0).any(), "front Gaussians must survive"


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    eye_z=st.floats(-50.0, -2.0),
    f=st.floats(50.0, 1200.0),
)
def test_project_matches_ref_hypothesis(seed, eye_z, f):
    rng = np.random.default_rng(seed)
    means, scales, quats = rand_gaussians3d(rng, BLOCK_N)
    view = lookat_viewmat((0.0, 0.0, eye_z))
    intr = np.array([f, f, 128.0, 128.0], dtype=np.float32)
    got = project_pallas(
        jnp.asarray(means), jnp.asarray(scales), jnp.asarray(quats),
        jnp.asarray(view), jnp.asarray(intr),
    )
    want = ref.project_ref(
        jnp.asarray(means), jnp.asarray(scales), jnp.asarray(quats),
        jnp.asarray(view), jnp.asarray(intr),
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-3, atol=1e-3
        )


# --------------------------------------------------------------- splatting

@pytest.mark.parametrize("mode", ["pixel", "group"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_splat_matches_ref(mode, seed):
    rng = np.random.default_rng(seed)
    mean2d, conic, color, opacity = rand_splat_inputs(rng)
    origin = np.array([96.0, 96.0], dtype=np.float32)
    rgb_in = np.zeros((PIXELS, 3), dtype=np.float32)
    t_in = np.ones(PIXELS, dtype=np.float32)
    got, want = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, mode
    )
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(want[0]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got[1]), np.asarray(want[1]), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("mode", ["pixel", "group"])
def test_splat_padding_rows_are_inert(mode):
    """Zero-opacity padding rows (rust chunking) must not change the tile."""
    rng = np.random.default_rng(11)
    mean2d, conic, color, opacity = rand_splat_inputs(rng)
    opacity[K_CHUNK // 2:] = 0.0
    origin = np.array([96.0, 96.0], dtype=np.float32)
    rgb_in = np.zeros((PIXELS, 3), dtype=np.float32)
    t_in = np.ones(PIXELS, dtype=np.float32)
    full, _ = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, mode
    )
    # Replace the padding rows' other attributes with garbage: must be inert.
    mean2d2 = mean2d.copy()
    mean2d2[K_CHUNK // 2:] = 1e6
    color2 = color.copy()
    color2[K_CHUNK // 2:] = 123.0
    garbage, _ = run_both_splat(
        mean2d2, conic, color2, opacity, origin, rgb_in, t_in, mode
    )
    np.testing.assert_allclose(
        np.asarray(full[0]), np.asarray(garbage[0]), rtol=1e-6, atol=1e-7
    )


@pytest.mark.parametrize("mode", ["pixel", "group"])
def test_splat_chunk_chaining(mode):
    """Blending 2x K_CHUNK in one ref scan == chaining two kernel calls."""
    rng = np.random.default_rng(3)
    m1, c1, col1, o1 = rand_splat_inputs(rng)
    m2, c2, col2, o2 = rand_splat_inputs(rng)
    origin = np.array([0.0, 0.0], dtype=np.float32)
    rgb = np.zeros((PIXELS, 3), dtype=np.float32)
    t = np.ones(PIXELS, dtype=np.float32)

    got1 = splat_tile_pallas(
        jnp.asarray(m1), jnp.asarray(c1), jnp.asarray(col1),
        jnp.asarray(o1), jnp.asarray(origin), jnp.asarray(rgb),
        jnp.asarray(t), alpha_mode=mode,
    )
    got2 = splat_tile_pallas(
        jnp.asarray(m2), jnp.asarray(c2), jnp.asarray(col2),
        jnp.asarray(o2), jnp.asarray(origin), got1[0], got1[1],
        alpha_mode=mode,
    )
    want = ref.splat_tile_ref(
        jnp.concatenate([jnp.asarray(m1), jnp.asarray(m2)]),
        jnp.concatenate([jnp.asarray(c1), jnp.asarray(c2)]),
        jnp.concatenate([jnp.asarray(col1), jnp.asarray(col2)]),
        jnp.concatenate([jnp.asarray(o1), jnp.asarray(o2)]),
        jnp.asarray(origin), jnp.asarray(rgb), jnp.asarray(t),
        alpha_mode=mode,
    )
    np.testing.assert_allclose(
        np.asarray(got2[0]), np.asarray(want[0]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got2[1]), np.asarray(want[1]), rtol=1e-4, atol=1e-5
    )


def test_group_mode_approximates_pixel_mode():
    """Paper Tbl. I: group-alpha is a close approximation, not identical.

    A Gaussian whose footprint straddles a group boundary can differ, but
    the image-level error must stay small (that is the accuracy claim).
    """
    rng = np.random.default_rng(5)
    mean2d, conic, color, opacity = rand_splat_inputs(rng, spread=20.0)
    origin = np.array([96.0, 96.0], dtype=np.float32)
    rgb_in = np.zeros((PIXELS, 3), dtype=np.float32)
    t_in = np.ones(PIXELS, dtype=np.float32)
    px, _ = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, "pixel"
    )
    gp, _ = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, "group"
    )
    err = np.abs(np.asarray(px[0]) - np.asarray(gp[0])).mean()
    assert err < 0.02, f"group-alpha approximation too lossy: {err}"


@pytest.mark.parametrize("mode", ["pixel", "group"])
def test_splat_transmittance_monotone(mode):
    """T never increases and stays in [0,1] after any chunk."""
    rng = np.random.default_rng(9)
    mean2d, conic, color, opacity = rand_splat_inputs(rng)
    origin = np.array([96.0, 96.0], dtype=np.float32)
    rgb_in = np.zeros((PIXELS, 3), dtype=np.float32)
    t_in = rng.uniform(0.0, 1.0, PIXELS).astype(np.float32)
    got, _ = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, mode
    )
    t_out = np.asarray(got[1])
    assert (t_out <= t_in + 1e-6).all()
    assert (t_out >= 0.0).all()


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ox=st.floats(0.0, 512.0),
    oy=st.floats(0.0, 512.0),
    mode=st.sampled_from(["pixel", "group"]),
)
def test_splat_matches_ref_hypothesis(seed, ox, oy, mode):
    rng = np.random.default_rng(seed)
    mean2d, conic, color, opacity = rand_splat_inputs(
        rng, origin=(ox, oy), spread=30.0
    )
    origin = np.array([ox, oy], dtype=np.float32)
    rgb_in = rng.uniform(0.0, 1.0, (PIXELS, 3)).astype(np.float32)
    t_in = rng.uniform(0.0, 1.0, PIXELS).astype(np.float32)
    got, want = run_both_splat(
        mean2d, conic, color, opacity, origin, rgb_in, t_in, mode
    )
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(want[0]), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(got[1]), np.asarray(want[1]), rtol=2e-4, atol=2e-5
    )
