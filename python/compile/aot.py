"""AOT lowering: jax entry points -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` rust crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per entry point in ``model.ENTRY_POINTS``
plus a ``manifest.json`` recording shapes for the rust ArtifactManifest
self-check.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ENTRY_POINTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn, specs = ENTRY_POINTS[name]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), specs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower a single entry point by name")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    names = [args.only] if args.only else list(ENTRY_POINTS)
    manifest = {}
    for name in names:
        text, specs = lower_entry(name)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {
            "file": path.name,
            "inputs": [list(s.shape) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = out_dir / "manifest.json"
    if manifest_path.exists() and args.only:
        existing = json.loads(manifest_path.read_text())
        existing.update(manifest)
        manifest = existing
    manifest_path.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
