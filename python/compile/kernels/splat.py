"""Layer-1 Pallas kernel: tile-based alpha blending (splatting).

One invocation blends a chunk of K depth-sorted Gaussians into one 16x16
pixel tile, carrying the (rgb, T) accumulator so the rust coordinator can
chain chunks and terminate early once the tile saturates.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation). The paper fixes GPU
*warp divergence*; a TPU has no warps, so we re-express the insight for a
vector/matrix unit:

  * The sequential front-to-back loop is restructured as a dense
    exclusive cumulative product over K (transmittance) followed by a
    (P,K) @ (K,3) weight-matrix product — the blend becomes an MXU matmul
    instead of K dependent steps.
  * alpha_mode="pixel": the keep-mask is evaluated per pixel — a (K,256)
    predicate matrix, the vector analogue of per-lane warp masking.
  * alpha_mode="group": the paper's SP-unit dataflow — alpha is checked
    once per 2x2 pixel group at the group centre, a (K,64) matrix
    broadcast to 4 pixels. 1/4 of the transcendental checks and a
    uniform, predication-free blend: exactly what the VPU wants.

The whole tile state lives in VMEM for the duration of the call
(footprint: K*(2+3+3+1)*4 B + 256*4*4 B ≈ 6.3 KB at K=64 — far under the
~16 MB VMEM budget; see DESIGN.md §Perf for the roofline estimate).

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ALPHA_CLAMP, ALPHA_THRESH, GROUP, TILE

PIXELS = TILE * TILE            # 256
GROUPS = (TILE // GROUP) ** 2   # 64
K_CHUNK = 64                    # Gaussians per call; rust chains chunks


def _alpha_matrix(mean2d, conic, opacity, centers):
    """(K,P) alpha matrix: alpha of Gaussian k at point p (clamped)."""
    dx = centers[None, :, 0] - mean2d[:, 0, None]  # (K,P)
    dy = centers[None, :, 1] - mean2d[:, 1, None]
    a = conic[:, 0, None]
    b = conic[:, 1, None]
    c = conic[:, 2, None]
    power = -0.5 * (a * dx * dx + c * dy * dy) - b * dx * dy
    power = jnp.minimum(power, 0.0)
    return jnp.minimum(opacity[:, None] * jnp.exp(power), ALPHA_CLAMP)


def _tile_points(origin_x, origin_y):
    """Pixel centres (P,2) and 2x2 group centres (G,2) of the tile."""
    idx = jax.lax.iota(jnp.float32, PIXELS)
    px = origin_x + jnp.mod(idx, TILE) + 0.5
    py = origin_y + jnp.floor(idx / TILE) + 0.5
    gidx = jax.lax.iota(jnp.float32, GROUPS)
    side = TILE // GROUP
    gx = origin_x + 2.0 * jnp.mod(gidx, side) + 1.0
    gy = origin_y + 2.0 * jnp.floor(gidx / side) + 1.0
    return (
        jnp.stack([px, py], axis=-1),
        jnp.stack([gx, gy], axis=-1),
    )


def _splat_kernel(group_alpha,
                  mean2d_ref, conic_ref, color_ref, opacity_ref, origin_ref,
                  rgb_in_ref, t_in_ref, rgb_out_ref, t_out_ref):
    px, gc = _tile_points(origin_ref[0], origin_ref[1])
    opacity = opacity_ref[...]

    alpha = _alpha_matrix(mean2d_ref[...], conic_ref[...], opacity, px)  # (K,P)

    if group_alpha:
        # SLTarch SP-unit dataflow: one alpha check per 2x2 group at the
        # group centre; keep-decision broadcast to the 4 pixels.
        galpha = _alpha_matrix(mean2d_ref[...], conic_ref[...], opacity, gc)
        gkeep = galpha >= ALPHA_THRESH  # (K,G)
        side = TILE // GROUP
        keep = (
            gkeep.reshape(K_CHUNK, side, side)
            .repeat(GROUP, axis=1)
            .repeat(GROUP, axis=2)
            .reshape(K_CHUNK, PIXELS)
        )
    else:
        # Canonical per-pixel check (the divergent GPU dataflow).
        keep = alpha >= ALPHA_THRESH

    keep = keep & (opacity[:, None] > 0.0)  # zero-opacity rows are padding
    eff = jnp.where(keep, alpha, 0.0)  # (K,P)

    # Front-to-back compositing as a dense scan-free form:
    #   T_k = t_in * prod_{j<k} (1 - eff_j)   (exclusive cumprod over K)
    #   rgb += sum_k (T_k * eff_k) * color_k  ((P,K) @ (K,3) matmul)
    one_minus = 1.0 - eff
    cum = jnp.cumprod(one_minus, axis=0)  # (K,P) inclusive
    t_in = t_in_ref[...]
    excl = jnp.concatenate([jnp.ones((1, PIXELS), cum.dtype), cum[:-1]], axis=0)
    weights = (excl * eff) * t_in[None, :]  # (K,P)
    rgb_out_ref[...] = rgb_in_ref[...] + jnp.dot(weights.T, color_ref[...])
    t_out_ref[...] = t_in * cum[-1]


def splat_tile_pallas(mean2d, conic, color, opacity, origin, rgb_in, t_in,
                      alpha_mode="pixel"):
    """Blend one K_CHUNK of sorted Gaussians into a 16x16 tile.

    Same contract as ``ref.splat_tile_ref``. alpha_mode selects the
    canonical per-pixel check ("pixel") or the SLTarch 2x2 group check
    ("group"). Returns (rgb_out (256,3), t_out (256,)).
    """
    assert mean2d.shape[0] == K_CHUNK
    f32 = jnp.float32
    kernel = functools.partial(_splat_kernel, alpha_mode == "group")
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((PIXELS, 3), f32),
            jax.ShapeDtypeStruct((PIXELS,), f32),
        ],
        interpret=True,
    )(mean2d, conic, color, opacity, origin, rgb_in, t_in)
