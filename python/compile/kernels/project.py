"""Layer-1 Pallas kernel: EWA projection of 3D Gaussians to screen space.

The paper's SPCore front end (projection unit, Fig. 8) computes, per
Gaussian: camera-space transform, perspective Jacobian, 2D covariance,
conic inversion and the 3-sigma radius. On TPU this is pure VPU work: we
tile the Gaussian batch into BLOCK_N-sized VMEM blocks (BlockSpec below)
and evaluate everything component-wise — no per-Gaussian 3x3 matmuls, so
every lane does identical arithmetic (the dataflow itself is
divergence-free, matching the fixed-function projection unit).

interpret=True: the CPU PJRT plugin cannot run Mosaic custom-calls; the
interpret path lowers to plain HLO that the rust runtime executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import COV2D_DILATION

BLOCK_N = 64  # Gaussians per grid step; one block resident in VMEM.


def _project_kernel(means_ref, scales_ref, quats_ref, view_ref, intr_ref,
                    mean2d_ref, conic_ref, depth_ref, radius_ref):
    fx = intr_ref[0]
    fy = intr_ref[1]
    cx = intr_ref[2]
    cy = intr_ref[3]

    mx = means_ref[:, 0]
    my = means_ref[:, 1]
    mz = means_ref[:, 2]

    # World -> camera (viewmat rows are the camera axes).
    r00, r01, r02, t0 = view_ref[0, 0], view_ref[0, 1], view_ref[0, 2], view_ref[0, 3]
    r10, r11, r12, t1 = view_ref[1, 0], view_ref[1, 1], view_ref[1, 2], view_ref[1, 3]
    r20, r21, r22, t2 = view_ref[2, 0], view_ref[2, 1], view_ref[2, 2], view_ref[2, 3]

    tx = r00 * mx + r01 * my + r02 * mz + t0
    ty = r10 * mx + r11 * my + r12 * mz + t1
    tz = r20 * mx + r21 * my + r22 * mz + t2
    tz_safe = jnp.where(jnp.abs(tz) < 1e-6, 1e-6, tz)
    zinv = 1.0 / tz_safe

    mean2d_ref[:, 0] = fx * tx * zinv + cx
    mean2d_ref[:, 1] = fy * ty * zinv + cy

    # Quaternion -> rotation matrix entries (normalised in-kernel).
    q = quats_ref[...]
    qn = q / (jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True)) + 1e-12)
    w, x, y, z = qn[:, 0], qn[:, 1], qn[:, 2], qn[:, 3]
    q00 = 1.0 - 2.0 * (y * y + z * z)
    q01 = 2.0 * (x * y - w * z)
    q02 = 2.0 * (x * z + w * y)
    q10 = 2.0 * (x * y + w * z)
    q11 = 1.0 - 2.0 * (x * x + z * z)
    q12 = 2.0 * (y * z - w * x)
    q20 = 2.0 * (x * z - w * y)
    q21 = 2.0 * (y * z + w * x)
    q22 = 1.0 - 2.0 * (x * x + y * y)

    sx2 = scales_ref[:, 0] * scales_ref[:, 0]
    sy2 = scales_ref[:, 1] * scales_ref[:, 1]
    sz2 = scales_ref[:, 2] * scales_ref[:, 2]

    # cov3d_ij = sum_k Rq[i,k] * s_k^2 * Rq[j,k]  (symmetric, 6 entries).
    c00 = q00 * q00 * sx2 + q01 * q01 * sy2 + q02 * q02 * sz2
    c01 = q00 * q10 * sx2 + q01 * q11 * sy2 + q02 * q12 * sz2
    c02 = q00 * q20 * sx2 + q01 * q21 * sy2 + q02 * q22 * sz2
    c11 = q10 * q10 * sx2 + q11 * q11 * sy2 + q12 * q12 * sz2
    c12 = q10 * q20 * sx2 + q11 * q21 * sy2 + q12 * q22 * sz2
    c22 = q20 * q20 * sx2 + q21 * q21 * sy2 + q22 * q22 * sz2

    # T = J @ W, with J the 2x3 perspective Jacobian.
    zinv2 = zinv * zinv
    j00 = fx * zinv
    j02 = -fx * tx * zinv2
    j11 = fy * zinv
    j12 = -fy * ty * zinv2

    T00 = j00 * r00 + j02 * r20
    T01 = j00 * r01 + j02 * r21
    T02 = j00 * r02 + j02 * r22
    T10 = j11 * r10 + j12 * r20
    T11 = j11 * r11 + j12 * r21
    T12 = j11 * r12 + j12 * r22

    # cov2d = T cov3d T^T (2x2 symmetric).
    # u_i = (cov3d @ T_row0)_i ; v_i = (cov3d @ T_row1)_i
    u0 = c00 * T00 + c01 * T01 + c02 * T02
    u1 = c01 * T00 + c11 * T01 + c12 * T02
    u2 = c02 * T00 + c12 * T01 + c22 * T02
    v0 = c00 * T10 + c01 * T11 + c02 * T12
    v1 = c01 * T10 + c11 * T11 + c12 * T12
    v2 = c02 * T10 + c12 * T11 + c22 * T12

    a = T00 * u0 + T01 * u1 + T02 * u2 + COV2D_DILATION
    b = T10 * u0 + T11 * u1 + T12 * u2
    c = T10 * v0 + T11 * v1 + T12 * v2 + COV2D_DILATION

    det = a * c - b * b
    det_safe = jnp.where(det <= 1e-12, 1e-12, det)
    conic_ref[:, 0] = c / det_safe
    conic_ref[:, 1] = -b / det_safe
    conic_ref[:, 2] = a / det_safe

    depth_ref[...] = tz

    mid = 0.5 * (a + c)
    lam = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam, 0.0)))
    visible = (tz > 0.2) & (det > 1e-12)
    radius_ref[...] = jnp.where(visible, radius, 0.0)


def project_pallas(means, scales, quats, viewmat, intr):
    """Project N Gaussians (N a multiple of BLOCK_N) to screen space.

    Same contract as ``ref.project_ref``; returns
    (mean2d (N,2), conic (N,3), depth (N,), radius (N,)).
    """
    n = means.shape[0]
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    grid = (n // BLOCK_N,)
    f32 = jnp.float32
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 4), lambda i: (i, 0)),
            pl.BlockSpec((4, 4), lambda i: (0, 0)),   # viewmat: broadcast
            pl.BlockSpec((4,), lambda i: (0,)),       # intrinsics: broadcast
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, 2), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 2), f32),
            jax.ShapeDtypeStruct((n, 3), f32),
            jax.ShapeDtypeStruct((n,), f32),
            jax.ShapeDtypeStruct((n,), f32),
        ],
        interpret=True,
    )(means, scales, quats, viewmat, intr)
