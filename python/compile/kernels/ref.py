"""Pure-jnp correctness oracles for the SLTarch kernels.

These are the ground-truth implementations of the two compute hot-spots
of the PBNR pipeline (paper Fig. 1):

  * ``project_ref``      — 3D Gaussian -> screen-space (EWA splatting
                           projection, identical maths to 3DGS/GSCore).
  * ``splat_tile_ref``   — front-to-back alpha blending of K depth-sorted
                           Gaussians over one 16x16 pixel tile, in the two
                           dataflows the paper contrasts:
                             alpha_mode="pixel" : canonical per-pixel
                                 alpha check (divergent on a GPU warp),
                             alpha_mode="group" : SLTarch 2x2 pixel-group
                                 alpha check (divergence-free, Sec. IV-C).

The Pallas kernels in ``project.py`` / ``splat.py`` must match these
(allclose within float32 tolerance); pytest + hypothesis sweeps enforce
that at build time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Blending constants (paper Sec. IV-C / 3DGS rasterizer).
ALPHA_THRESH = 1.0 / 255.0  # transparency cut-off for integration
ALPHA_CLAMP = 0.99          # max per-sample alpha (numerical guard)
COV2D_DILATION = 0.3        # EWA low-pass dilation added to cov2d diagonal

TILE = 16                   # tile side in pixels
GROUP = 2                   # pixel-group side (SP unit granularity)


def quat_to_rotmat(q):
    """Normalized quaternion (w,x,y,z) -> 3x3 rotation matrix. q: (...,4)."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1.0 - 2.0 * (y * y + z * z)
    r01 = 2.0 * (x * y - w * z)
    r02 = 2.0 * (x * z + w * y)
    r10 = 2.0 * (x * y + w * z)
    r11 = 1.0 - 2.0 * (x * x + z * z)
    r12 = 2.0 * (y * z - w * x)
    r20 = 2.0 * (x * z - w * y)
    r21 = 2.0 * (y * z + w * x)
    r22 = 1.0 - 2.0 * (x * x + y * y)
    rows = [
        jnp.stack([r00, r01, r02], axis=-1),
        jnp.stack([r10, r11, r12], axis=-1),
        jnp.stack([r20, r21, r22], axis=-1),
    ]
    return jnp.stack(rows, axis=-2)


def project_ref(means, scales, quats, viewmat, intr):
    """EWA projection of N 3D Gaussians to screen space.

    Args:
      means:   (N,3) world-space centres.
      scales:  (N,3) per-axis standard deviations (linear, not log).
      quats:   (N,4) orientations, (w,x,y,z), not necessarily normalized.
      viewmat: (4,4) world->camera, row-major.
      intr:    (4,)  pinhole intrinsics fx, fy, cx, cy.

    Returns:
      mean2d: (N,2) pixel-space centres.
      conic:  (N,3) inverse 2D covariance (a,b,c) with
              power = -0.5*(a dx^2 + c dy^2) - b dx dy.
      depth:  (N,)  camera-space z.
      radius: (N,)  3-sigma screen-space radius in pixels (0 if culled).
    """
    fx, fy, cx, cy = intr[0], intr[1], intr[2], intr[3]
    R = viewmat[:3, :3]
    t = viewmat[:3, 3]

    # Camera-space centres.
    tc = means @ R.T + t  # (N,3)
    tz = tc[:, 2]
    # Guard against division by ~0 depth; culled later via radius.
    tz_safe = jnp.where(jnp.abs(tz) < 1e-6, 1e-6, tz)

    mean2d = jnp.stack(
        [fx * tc[:, 0] / tz_safe + cx, fy * tc[:, 1] / tz_safe + cy], axis=-1
    )

    # 3D covariance = R_q diag(s^2) R_q^T.
    Rq = quat_to_rotmat(quats)  # (N,3,3)
    M = Rq * (scales[:, None, :] ** 2)  # R * diag(s^2)
    cov3d = M @ jnp.swapaxes(Rq, -1, -2)  # (N,3,3)

    # Perspective Jacobian rows (EWA).
    zinv = 1.0 / tz_safe
    zinv2 = zinv * zinv
    n = means.shape[0]
    J = jnp.zeros((n, 2, 3), dtype=means.dtype)
    J = J.at[:, 0, 0].set(fx * zinv)
    J = J.at[:, 0, 2].set(-fx * tc[:, 0] * zinv2)
    J = J.at[:, 1, 1].set(fy * zinv)
    J = J.at[:, 1, 2].set(-fy * tc[:, 1] * zinv2)

    W = R[None, :, :]  # world->camera rotation
    T_ = J @ W  # (N,2,3)
    cov2d = T_ @ cov3d @ jnp.swapaxes(T_, -1, -2)  # (N,2,2)
    a = cov2d[:, 0, 0] + COV2D_DILATION
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + COV2D_DILATION

    det = a * c - b * b
    det_safe = jnp.where(det <= 1e-12, 1e-12, det)
    conic = jnp.stack([c / det_safe, -b / det_safe, a / det_safe], axis=-1)

    # 3-sigma radius from the larger eigenvalue of cov2d.
    mid = 0.5 * (a + c)
    lam = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam, 0.0)))
    visible = (tz > 0.2) & (det > 1e-12)
    radius = jnp.where(visible, radius, 0.0)

    return mean2d, conic, tz, radius


def pixel_centers(tile_origin):
    """(256,2) pixel-centre coordinates of a TILE x TILE tile."""
    ys, xs = jnp.meshgrid(
        jnp.arange(TILE, dtype=jnp.float32),
        jnp.arange(TILE, dtype=jnp.float32),
        indexing="ij",
    )
    px = tile_origin[0] + xs.reshape(-1) + 0.5
    py = tile_origin[1] + ys.reshape(-1) + 0.5
    return jnp.stack([px, py], axis=-1)  # (256,2)


def group_centers(tile_origin):
    """(64,2) centre coordinates of the 2x2 pixel groups of a tile."""
    g = TILE // GROUP
    ys, xs = jnp.meshgrid(
        jnp.arange(g, dtype=jnp.float32),
        jnp.arange(g, dtype=jnp.float32),
        indexing="ij",
    )
    # Group covers pixel centres {2g+0.5, 2g+1.5} -> centre at 2g+1.
    px = tile_origin[0] + 2.0 * xs.reshape(-1) + 1.0
    py = tile_origin[1] + 2.0 * ys.reshape(-1) + 1.0
    return jnp.stack([px, py], axis=-1)  # (64,2)


def gauss_power(conic, d):
    """Gaussian exponent power. conic: (...,3), d: (...,2) offset."""
    a, b, c = conic[..., 0], conic[..., 1], conic[..., 2]
    dx, dy = d[..., 0], d[..., 1]
    return -0.5 * (a * dx * dx + c * dy * dy) - b * dx * dy


def splat_tile_ref(
    mean2d, conic, color, opacity, tile_origin, rgb_in, t_in, alpha_mode
):
    """Blend K front-to-back sorted Gaussians over one 16x16 tile.

    Args:
      mean2d:  (K,2)  screen-space centres.
      conic:   (K,3)  inverse 2D covariances.
      color:   (K,3)  RGB.
      opacity: (K,)   base opacity in [0,1]; entries <=0 are padding and
                      contribute nothing (L3 pads chunks with zeros).
      tile_origin: (2,) pixel coords of the tile's top-left corner.
      rgb_in:  (256,3) accumulated colour carried across K-chunks.
      t_in:    (256,)  remaining transmittance carried across K-chunks.
      alpha_mode: "pixel" (canonical) or "group" (SLTarch 2x2 group check).

    Returns (rgb_out, t_out) with the same shapes as the carried state.
    """
    px = pixel_centers(tile_origin)  # (256,2)
    gc = group_centers(tile_origin)  # (64,2)

    def body(carry, g):
        rgb, t = carry
        m, cn, col, op = g
        d = px - m[None, :]  # (256,2)
        power = jnp.minimum(gauss_power(cn[None, :], d), 0.0)  # (256,)
        alpha = jnp.minimum(op * jnp.exp(power), ALPHA_CLAMP)  # (256,)

        if alpha_mode == "pixel":
            # Canonical: each pixel decides for itself (warp-divergent).
            keep = alpha >= ALPHA_THRESH
        else:
            # SLTarch: one alpha-check per 2x2 group at the group centre;
            # the decision is broadcast to all 4 pixels (divergence-free).
            gd = gc - m[None, :]
            gpower = jnp.minimum(gauss_power(cn[None, :], gd), 0.0)
            galpha = jnp.minimum(op * jnp.exp(gpower), ALPHA_CLAMP)
            gkeep = galpha >= ALPHA_THRESH  # (64,)
            side = TILE // GROUP
            keep = (
                gkeep.reshape(side, side)
                .repeat(GROUP, axis=0)
                .repeat(GROUP, axis=1)
                .reshape(-1)
            )
        keep = keep & (op > 0.0)
        eff = jnp.where(keep, alpha, 0.0)  # (256,)
        rgb = rgb + (t * eff)[:, None] * col[None, :]
        t = t * (1.0 - eff)
        return (rgb, t), None

    (rgb, t), _ = jax.lax.scan(
        body, (rgb_in, t_in), (mean2d, conic, color, opacity)
    )
    return rgb, t
