"""Layer-2 JAX compute graph for the SLTarch PBNR pipeline.

Defines the fixed-shape entry points that ``aot.py`` lowers to HLO text
for the rust runtime (one artifact per entry point). Python never runs at
render time: the rust coordinator pads/chunks live workloads to these
static shapes.

Entry points (shapes chosen for the rust batcher; see
rust/src/runtime/artifacts.rs which mirrors this table):

  project_n256   : project a batch of 256 Gaussians
  splat_pixel_k64: blend 64 sorted Gaussians into a 16x16 tile,
                   canonical per-pixel alpha check
  splat_group_k64: same, SLTarch 2x2 pixel-group alpha check (Sec. IV-C)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.project import BLOCK_N, project_pallas
from .kernels.splat import K_CHUNK, PIXELS, splat_tile_pallas

PROJECT_N = 256  # Gaussians per projection batch (multiple of BLOCK_N)
assert PROJECT_N % BLOCK_N == 0


def project_batch(means, scales, quats, viewmat, intr):
    """Project PROJECT_N Gaussians; returns (mean2d, conic, depth, radius).

    Thin L2 wrapper: the entire computation lives in the L1 Pallas kernel
    so the lowered HLO is a single fused region (no L2-side recompute).
    """
    return tuple(project_pallas(means, scales, quats, viewmat, intr))


def splat_tile_pixel(mean2d, conic, color, opacity, origin, rgb_in, t_in):
    """Canonical splatting chunk: per-pixel alpha check (divergent)."""
    rgb, t = splat_tile_pallas(
        mean2d, conic, color, opacity, origin, rgb_in, t_in,
        alpha_mode="pixel",
    )
    return rgb, t


def splat_tile_group(mean2d, conic, color, opacity, origin, rgb_in, t_in):
    """SLTarch splatting chunk: 2x2 group alpha check (divergence-free)."""
    rgb, t = splat_tile_pallas(
        mean2d, conic, color, opacity, origin, rgb_in, t_in,
        alpha_mode="group",
    )
    return rgb, t


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# name -> (callable, example argument shapes). aot.py lowers each entry;
# the rust ArtifactManifest (runtime/artifacts.rs) mirrors this table.
ENTRY_POINTS = {
    "project_n256": (
        project_batch,
        (_f32(PROJECT_N, 3), _f32(PROJECT_N, 3), _f32(PROJECT_N, 4),
         _f32(4, 4), _f32(4)),
    ),
    "splat_pixel_k64": (
        splat_tile_pixel,
        (_f32(K_CHUNK, 2), _f32(K_CHUNK, 3), _f32(K_CHUNK, 3),
         _f32(K_CHUNK), _f32(2), _f32(PIXELS, 3), _f32(PIXELS)),
    ),
    "splat_group_k64": (
        splat_tile_group,
        (_f32(K_CHUNK, 2), _f32(K_CHUNK, 3), _f32(K_CHUNK, 3),
         _f32(K_CHUNK), _f32(2), _f32(PIXELS, 3), _f32(PIXELS)),
    ),
}
